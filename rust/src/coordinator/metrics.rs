//! Serving metrics: counters, latency distribution, and the simulated
//! device-time/energy overlay — per node, plus fleet-wide aggregation.

use crate::obsv::Attribution;

/// Online latency/throughput accumulator with fixed percentile tracking.
///
/// Recording stays O(1) (append + running sum); percentile reads go
/// through a **lazily rebuilt sorted cache** that stays valid until new
/// samples arrive (the raw vector is append-only, so `len` equality is the
/// validity test). One [`Metrics::render`] therefore sorts at most once,
/// and repeated [`Metrics::latency_pct`] calls are O(1) lookups — the old
/// path cloned and re-sorted the full history on every percentile read.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub errors: u64,
    pub tokens_out: u64,
    /// Raw samples in arrival order; append-only.
    latencies_s: Vec<f64>,
    latency_sum_s: f64,
    /// Sorted view of `latencies_s`; valid iff the lengths match.
    sorted_cache: std::cell::RefCell<Vec<f64>>,
    pub wall_prefill_s: f64,
    pub wall_decode_s: f64,
    /// Simulated device seconds for the same workload (the §4 overlay).
    pub simulated_device_s: f64,
    /// Simulated device energy for the same workload, joules — prefill at
    /// the TDP envelope, decode at the §4.4 calibrated power.
    pub simulated_energy_j: f64,
    /// Decode rounds stepped (continuous batching: one per engine round).
    pub batches: u64,
    /// Total sequences stepped across all rounds (drives mean batch size).
    batch_seqs: u64,
    /// Sequences evicted back to the waiting queue under KV page pressure
    /// (their device KV was dropped).
    pub preemptions: u64,
    /// Preempted sequences that re-entered decode (prefill recomputed and
    /// generated tokens replayed).
    pub resumes: u64,
    /// Simulated device seconds spent recomputing work lost to preemption
    /// — the price paid for the admission headroom eviction bought.
    pub wasted_prefill_s: f64,
    /// Requests this node pulled off a peer's queue while idle (on a
    /// tenant rollup: requests of this tenant that were stolen).
    pub steals: u64,
    /// Times the waiting-queue aging gate engaged for a parked preempted
    /// sequence (new admissions held back until it resumed).
    pub aged_promotions: u64,
    /// Prompt KV blocks served by pinning an already-resident block
    /// (prefix-cache hits).
    pub prefix_hits: u64,
    /// Prompt KV blocks allocated fresh at admission (prefix-cache
    /// misses).
    pub prefix_misses: u64,
    /// Shared KV blocks privatized on first write (copy-on-write).
    pub cow_copies: u64,
    /// Prefix hits served from the **reclaimable cache** — refcount-zero
    /// blocks the radix tree retained past their last holder and a
    /// returning prompt re-pinned (a subset of `prefix_hits`; the rest
    /// were live-shared with a concurrent holder).
    pub resurrected_blocks: u64,
    /// Cached (refcount-zero) blocks reclaimed under allocation pressure
    /// — tree-unlinked and freed, their history gone.
    pub reclaimed_blocks: u64,
    /// Bytes currently held by the reclaimable cache tier — a gauge per
    /// node (latest pager snapshot), a fleet sum under [`Metrics::merge`].
    pub cached_bytes: u64,
    /// Simulated prefill seconds *not* spent because the positions were
    /// already resident in shared prefix blocks — the saved side of the
    /// ledger `wasted_prefill_s` is the wasted side of.
    pub saved_prefill_s: f64,
    /// Share of `saved_prefill_s` earned by **resurrected** cached blocks
    /// (no live sharer existed; the tree alone kept the KV). The
    /// remainder was saved by live sharing, the PR 5 mechanism.
    pub saved_prefill_resurrected_s: f64,
    /// Preemption victims whose KV pages were parked in host RAM instead
    /// of dropped (the PCIe-priced swap path).
    pub swap_outs: u64,
    /// Swapped-out sequences restored from host RAM (no recompute).
    pub swap_ins: u64,
    /// Bytes moved over the host link by swap-outs and swap-ins.
    pub swap_bytes: u64,
    /// Simulated PCIe seconds spent moving swapped pages (the §3 model at
    /// the card's link width).
    pub swap_transfer_s: f64,
    /// Simulated device seconds of recompute avoided by swapping, net of
    /// the transfer paid for it — what the swap-vs-recompute chooser
    /// bought.
    pub saved_recompute_s: f64,
    /// Share of `swap_transfer_s` hidden under concurrent decode rounds
    /// (swap–decode overlap: DMA and SM compute proceed in parallel).
    pub swap_overlapped_s: f64,
    /// Share of `swap_transfer_s` the engine actually stalled for — the
    /// overhang past the concurrent round. With overlap modeling off the
    /// whole transfer lands here (the serial-charge baseline).
    pub swap_stalled_s: f64,
    /// Parked sequences restored onto a *different* card than the one
    /// that swapped them out (live migration over the fleet KV fabric) —
    /// includes in-flight steals of parked work.
    pub migrations: u64,
    /// Foreign-claim attempts the migration hysteresis gate deferred: a
    /// parked sequence existed but was too young or its owner idle
    /// enough to resume it next round — the thrash a grab would cause.
    pub migration_deferrals: u64,
    /// Requests routed to a node because it held part of their prompt's
    /// prefix chain (the fleet directory reported nonzero matched depth).
    pub affine_routes: u64,
    /// In-flight sequences rescued off a dead node (re-queued and
    /// replayed to a bit-identical state on a healthy card).
    pub rescued_seqs: u64,
    /// In-flight sequences a node death lost terminally (rescue disabled
    /// or no path back to dispatch) — answered with an error, not hung.
    pub lost_seqs: u64,
    /// Requests bounced back to dispatch by a transient worker failure
    /// and re-attempted under the bounded-backoff policy.
    pub retries: u64,
    /// Requests failed because their wall-clock deadline passed before a
    /// card could serve them.
    pub deadline_misses: u64,
    /// Requests carrying a tenant SLO contract that reached a terminal
    /// response (served or failed) — the attainment denominator.
    pub slo_eligible: u64,
    /// Of those, requests whose end-to-end latency met the contract —
    /// the attainment numerator.
    pub slo_met: u64,
    /// Requests shed at submit by adaptive admission control: their
    /// predicted completion already violated the tenant's SLO, so no
    /// prefill was wasted on them.
    pub admission_sheds: u64,
    /// Faults this node absorbed without dying (stalls, throttles, link
    /// downgrades, VRAM page loss) — the degradation-ladder trigger count.
    pub degrade_events: u64,
    /// Swap-ins that found corrupt host pages and fell back to recompute.
    pub swap_in_failures: u64,
    /// Simulated seconds of prior progress preserved by rescues (work the
    /// client did not lose when the card died) — the recovered side of
    /// the wasted-vs-recovered ledger.
    pub rescue_kept_s: f64,
    /// Simulated seconds spent replaying rescued tokens on the new card —
    /// the wasted side (the fault's price, paid to keep tokens
    /// bit-identical).
    pub rescue_replay_s: f64,
    /// Downtime over closed node incidents, seconds (from the router's
    /// MTTR ledger; snapshotted into node metrics at reporting time).
    pub fault_downtime_s: f64,
    /// Closed node incidents — with `fault_downtime_s`, yields MTTR.
    pub fault_recoveries: u64,
    /// Latency-attribution rollup over retired requests: wall queueing
    /// delay plus the simulated per-phase ledger (prefill / decode /
    /// stall / replay seconds). Recorded at retire on both the serving
    /// node's metrics and the billing tenant's rollup; summed fleet-wide
    /// by [`Metrics::merge`].
    pub attrib: Attribution,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// O(1): the serving workers call this under their metrics mutex on
    /// every retired request, so no sorting happens here.
    pub fn record_response(&mut self, latency_s: f64, tokens: usize, ok: bool) {
        self.requests += 1;
        if !ok {
            self.errors += 1;
        }
        self.tokens_out += tokens as u64;
        self.latencies_s.push(latency_s);
        self.latency_sum_s += latency_s;
    }

    /// Read through the sorted cache, rebuilding it only when samples were
    /// recorded since the last read.
    fn with_sorted<R>(&self, f: impl FnOnce(&[f64]) -> R) -> R {
        let mut cache = self.sorted_cache.borrow_mut();
        if cache.len() != self.latencies_s.len() {
            cache.clear();
            cache.extend_from_slice(&self.latencies_s);
            cache.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        f(&cache)
    }

    /// Score one terminal response against its tenant's SLO contract —
    /// a no-op for contract-less traffic. Failed requests score as
    /// misses through `met = false`.
    pub fn record_slo(&mut self, met: bool) {
        self.slo_eligible += 1;
        if met {
            self.slo_met += 1;
        }
    }

    /// SLO attainment over contracted traffic; `None` when no contracted
    /// request has terminated (attainment is then undefined, not 100%).
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.slo_eligible == 0 {
            None
        } else {
            Some(self.slo_met as f64 / self.slo_eligible as f64)
        }
    }

    /// Record one decode round of `size` concurrent sequences.
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_seqs += size as u64;
    }

    /// Latency percentile (0.0–1.0). None when empty. O(1) when nothing
    /// was recorded since the last read; one sort otherwise.
    pub fn latency_pct(&self, p: f64) -> Option<f64> {
        if self.latencies_s.is_empty() {
            return None;
        }
        Some(self.with_sorted(|xs| {
            let idx = ((xs.len() as f64 - 1.0) * p).round() as usize;
            xs[idx.min(xs.len() - 1)]
        }))
    }

    pub fn mean_latency(&self) -> Option<f64> {
        if self.latencies_s.is_empty() {
            None
        } else {
            Some(self.latency_sum_s / self.latencies_s.len() as f64)
        }
    }

    /// Mean decode-round width — the continuous-batching occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_seqs as f64 / self.batches as f64
        }
    }

    /// Decode throughput over the measured wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let t = self.wall_prefill_s + self.wall_decode_s;
        if t == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / t
        }
    }

    /// Simulated device throughput: served tokens over simulated device
    /// seconds for the same schedule.
    pub fn sim_tokens_per_sec(&self) -> f64 {
        if self.simulated_device_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.simulated_device_s
        }
    }

    /// Simulated energy efficiency, tokens/joule.
    pub fn sim_tokens_per_joule(&self) -> f64 {
        if self.simulated_energy_j == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.simulated_energy_j
        }
    }

    /// Speed ratio: how much faster/slower the simulated device is than
    /// this host for the same served work.
    pub fn sim_speedup_vs_host(&self) -> Option<f64> {
        if self.simulated_device_s == 0.0 {
            None
        } else {
            Some((self.wall_prefill_s + self.wall_decode_s) / self.simulated_device_s)
        }
    }

    /// Fold another node's metrics into this one (fleet aggregation).
    /// Latency histories concatenate; the sorted cache rebuilds itself on
    /// the next percentile read (its length no longer matches).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.errors += other.errors;
        self.tokens_out += other.tokens_out;
        self.wall_prefill_s += other.wall_prefill_s;
        self.wall_decode_s += other.wall_decode_s;
        self.simulated_device_s += other.simulated_device_s;
        self.simulated_energy_j += other.simulated_energy_j;
        self.batches += other.batches;
        self.batch_seqs += other.batch_seqs;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.wasted_prefill_s += other.wasted_prefill_s;
        self.steals += other.steals;
        self.aged_promotions += other.aged_promotions;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.cow_copies += other.cow_copies;
        self.resurrected_blocks += other.resurrected_blocks;
        self.reclaimed_blocks += other.reclaimed_blocks;
        self.cached_bytes += other.cached_bytes;
        self.saved_prefill_s += other.saved_prefill_s;
        self.saved_prefill_resurrected_s += other.saved_prefill_resurrected_s;
        self.swap_outs += other.swap_outs;
        self.swap_ins += other.swap_ins;
        self.swap_bytes += other.swap_bytes;
        self.swap_transfer_s += other.swap_transfer_s;
        self.saved_recompute_s += other.saved_recompute_s;
        self.swap_overlapped_s += other.swap_overlapped_s;
        self.swap_stalled_s += other.swap_stalled_s;
        self.migrations += other.migrations;
        self.migration_deferrals += other.migration_deferrals;
        self.affine_routes += other.affine_routes;
        self.rescued_seqs += other.rescued_seqs;
        self.lost_seqs += other.lost_seqs;
        self.retries += other.retries;
        self.deadline_misses += other.deadline_misses;
        self.slo_eligible += other.slo_eligible;
        self.slo_met += other.slo_met;
        self.admission_sheds += other.admission_sheds;
        self.degrade_events += other.degrade_events;
        self.swap_in_failures += other.swap_in_failures;
        self.rescue_kept_s += other.rescue_kept_s;
        self.rescue_replay_s += other.rescue_replay_s;
        self.fault_downtime_s += other.fault_downtime_s;
        self.fault_recoveries += other.fault_recoveries;
        self.attrib.merge(&other.attrib);
        self.latency_sum_s += other.latency_sum_s;
        self.latencies_s.extend_from_slice(&other.latencies_s);
    }

    /// Mean time to recovery over closed node incidents, seconds.
    pub fn mttr_s(&self) -> Option<f64> {
        if self.fault_recoveries == 0 {
            None
        } else {
            Some(self.fault_downtime_s / self.fault_recoveries as f64)
        }
    }

    /// Overwrite the prefix-cache counters from a pager's cumulative
    /// [`crate::coordinator::kv::PrefixStats`] snapshot. Assignment, not
    /// accumulation: each node's pager is the sole source for its node
    /// metrics, and [`Metrics::merge`] sums across nodes as usual.
    pub fn sync_prefix(&mut self, s: crate::coordinator::kv::PrefixStats) {
        self.prefix_hits = s.hit_blocks;
        self.prefix_misses = s.miss_blocks;
        self.cow_copies = s.cow_copies;
        self.resurrected_blocks = s.resurrected_blocks;
        self.reclaimed_blocks = s.reclaimed_blocks;
    }

    /// Overwrite the cached-tier byte gauge from the pager's current
    /// ledger (same assign-not-accumulate convention as
    /// [`Metrics::sync_prefix`]; `merge` sums gauges into a fleet total).
    pub fn sync_cache(&mut self, cached_bytes: u64) {
        self.cached_bytes = cached_bytes;
    }

    /// Prefix-cache block hit rate over all prompt blocks admitted.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// Render a summary block in one pass: at most one cache rebuild for
    /// all three latency statistics, everything else O(1) counters.
    pub fn render(&self) -> String {
        format!(
            "requests={} errors={} tokens={} mean_batch={:.2}\n\
             prefix: hits={} misses={} ({:.0}%) cow={} saved_sim={:.4}s affine_routes={}\n\
             cache: resurrected={} reclaimed={} cached={:.1} MiB \
             saved_resurrected_sim={:.4}s\n\
             swap: out={} in={} {:.1} MiB link_s={:.4} saved_sim={:.4}s\n\
             fabric: migrations={} deferred={} overlap hidden={:.4}s stalled={:.4}s\n\
             preempt: evicted={} resumed={} wasted_sim={:.4}s aged={} | steals={}\n\
             faults: rescued={} lost={} retries={} deadline_miss={} degraded={} \
             swapfail={} kept={:.4}s replayed={:.4}s mttr={}\n\
             slo: eligible={} met={} attainment={} admission_sheds={}\n\
             attrib: queue={:.4}s prefill={:.4}s decode={:.4}s stall={:.4}s replay={:.4}s\n\
             latency mean={:.1}ms p50={:.1}ms p99={:.1}ms p99.9={:.1}ms\n\
             host: prefill {:.3}s decode {:.3}s → {:.1} tok/s\n\
             simulated device time: {:.4}s ({}× host)  energy {:.2}J → {:.1} tok/J",
            self.requests,
            self.errors,
            self.tokens_out,
            self.mean_batch_size(),
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_hit_rate() * 100.0,
            self.cow_copies,
            self.saved_prefill_s,
            self.affine_routes,
            self.resurrected_blocks,
            self.reclaimed_blocks,
            self.cached_bytes as f64 / (1u64 << 20) as f64,
            self.saved_prefill_resurrected_s,
            self.swap_outs,
            self.swap_ins,
            self.swap_bytes as f64 / (1u64 << 20) as f64,
            self.swap_transfer_s,
            self.saved_recompute_s,
            self.migrations,
            self.migration_deferrals,
            self.swap_overlapped_s,
            self.swap_stalled_s,
            self.preemptions,
            self.resumes,
            self.wasted_prefill_s,
            self.aged_promotions,
            self.steals,
            self.rescued_seqs,
            self.lost_seqs,
            self.retries,
            self.deadline_misses,
            self.degrade_events,
            self.swap_in_failures,
            self.rescue_kept_s,
            self.rescue_replay_s,
            self.mttr_s()
                .map(|s| format!("{:.1}ms", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            self.slo_eligible,
            self.slo_met,
            self.slo_attainment()
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "-".into()),
            self.admission_sheds,
            self.attrib.queue_s,
            self.attrib.prefill_s,
            self.attrib.decode_s,
            self.attrib.stall_s,
            self.attrib.replay_s,
            self.mean_latency().unwrap_or(0.0) * 1e3,
            self.latency_pct(0.5).unwrap_or(0.0) * 1e3,
            self.latency_pct(0.99).unwrap_or(0.0) * 1e3,
            self.latency_pct(0.999).unwrap_or(0.0) * 1e3,
            self.wall_prefill_s,
            self.wall_decode_s,
            self.tokens_per_sec(),
            self.simulated_device_s,
            self.sim_speedup_vs_host()
                .map(|s| format!("{s:.1}"))
                .unwrap_or_else(|| "-".into()),
            self.simulated_energy_j,
            self.sim_tokens_per_joule(),
        )
    }
}

/// Jain's fairness index over per-tenant service shares: `(Σx)² / (n·Σx²)`,
/// 1.0 when every share is equal, → 1/n when one tenant takes everything.
/// Shares should be normalized by tenant weight before calling. Empty or
/// all-zero inputs read as perfectly fair (no service was given unfairly).
pub fn jain_index(shares: &[f64]) -> f64 {
    let n = shares.len() as f64;
    let sum: f64 = shares.iter().sum();
    let sq: f64 = shares.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        1.0
    } else {
        (sum * sum) / (n * sq)
    }
}

/// Per-node metric snapshots plus fleet-wide aggregation — what the fleet
/// engine reports so "N recycled cards vs one A100" is answerable in
/// tokens/s *and* tokens/joule.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// `(device name, node metrics)`, in node order.
    pub nodes: Vec<(&'static str, Metrics)>,
    /// `(tenant name, tenant rollup)`, in tenant-id order. A request is
    /// counted on the node that served it **and** the tenant it billed
    /// to; requests shed at the QoS dispatch stage (energy budget, no
    /// healthy node) appear only in their tenant's rollup — `total()`
    /// stays the node-side serving aggregate.
    pub tenants: Vec<(String, Metrics)>,
}

impl FleetMetrics {
    /// Fleet-wide totals: every counter summed, latency histories merged.
    /// Note the wall/sim **seconds are summed busy time across cards**, so
    /// `total().tokens_per_sec()` is a per-card average rate; the fleet's
    /// concurrent rate is [`FleetMetrics::sim_tokens_per_sec`].
    pub fn total(&self) -> Metrics {
        let mut out = Metrics::new();
        for (_, m) in &self.nodes {
            out.merge(m);
        }
        out
    }

    /// Fleet simulated throughput: cards decode concurrently, so the fleet
    /// rate is the **sum** of per-card simulated rates (nodes that served
    /// nothing contribute zero).
    pub fn sim_tokens_per_sec(&self) -> f64 {
        self.nodes.iter().map(|(_, m)| m.sim_tokens_per_sec()).sum()
    }

    /// Fleet energy efficiency: total tokens over total simulated joules.
    pub fn sim_tokens_per_joule(&self) -> f64 {
        self.total().sim_tokens_per_joule()
    }

    /// Render per-node lines, per-tenant lines (when more than the
    /// default tenant exists), plus the fleet aggregate.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.nodes {
            out.push_str(&format!(
                "node {name:<22} req={:<4} tok={:<6} sim {:>8.1} tok/s  {:>6.1} tok/J  \
                 attrib q={:.3} pf={:.3} de={:.3} st={:.3} rp={:.3}\n",
                m.requests,
                m.tokens_out,
                m.sim_tokens_per_sec(),
                m.sim_tokens_per_joule(),
                m.attrib.queue_s,
                m.attrib.prefill_s,
                m.attrib.decode_s,
                m.attrib.stall_s,
                m.attrib.replay_s,
            ));
        }
        if self.tenants.len() > 1 {
            for (name, m) in &self.tenants {
                out.push_str(&format!(
                    "tenant {name:<20} req={:<4} err={:<3} tok={:<6} p99 {:>7.1}ms  \
                     energy {:>8.2}J stolen={}  attrib q={:.3} pf={:.3} de={:.3} \
                     st={:.3} rp={:.3}\n",
                    m.requests,
                    m.errors,
                    m.tokens_out,
                    m.latency_pct(0.99).unwrap_or(0.0) * 1e3,
                    m.simulated_energy_j,
                    m.steals,
                    m.attrib.queue_s,
                    m.attrib.prefill_s,
                    m.attrib.decode_s,
                    m.attrib.stall_s,
                    m.attrib.replay_s,
                ));
            }
        }
        let total = self.total();
        out.push_str(&format!(
            "fleet ({} nodes): sim {:.1} tok/s  {:.1} tok/J\n{}",
            self.nodes.len(),
            self.sim_tokens_per_sec(),
            total.sim_tokens_per_joule(),
            total.render(),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    #[test]
    fn percentiles_order_correctly() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_response(i as f64, 1, true);
        }
        assert!(m.latency_pct(0.5).unwrap() <= m.latency_pct(0.99).unwrap());
        assert_eq!(m.latency_pct(0.0).unwrap(), 1.0);
        assert_eq!(m.latency_pct(1.0).unwrap(), 100.0);
    }

    #[test]
    fn empty_metrics_are_none_or_zero() {
        let m = Metrics::new();
        assert!(m.latency_pct(0.5).is_none());
        assert!(m.mean_latency().is_none());
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.sim_tokens_per_sec(), 0.0);
        assert_eq!(m.sim_tokens_per_joule(), 0.0);
    }

    #[test]
    fn errors_counted_separately() {
        let mut m = Metrics::new();
        m.record_response(0.1, 0, false);
        m.record_response(0.1, 5, true);
        assert_eq!(m.requests, 2);
        assert_eq!(m.errors, 1);
        assert_eq!(m.tokens_out, 5);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut m = Metrics::new();
        m.record_response(0.25, 8, true);
        m.record_batch(2);
        m.wall_decode_s = 1.0;
        m.simulated_device_s = 0.1;
        m.simulated_energy_j = 4.0;
        m.preemptions = 3;
        m.resumes = 2;
        m.wasted_prefill_s = 0.5;
        m.steals = 4;
        m.aged_promotions = 1;
        m.prefix_hits = 6;
        m.prefix_misses = 2;
        m.cow_copies = 1;
        m.resurrected_blocks = 4;
        m.reclaimed_blocks = 2;
        m.cached_bytes = 2 << 20;
        m.saved_prefill_s = 0.25;
        m.saved_prefill_resurrected_s = 0.125;
        m.migration_deferrals = 3;
        m.swap_outs = 2;
        m.swap_ins = 2;
        m.swap_bytes = 3 << 20;
        m.swap_transfer_s = 0.125;
        m.saved_recompute_s = 1.5;
        m.rescued_seqs = 2;
        m.lost_seqs = 1;
        m.retries = 3;
        m.deadline_misses = 2;
        m.degrade_events = 4;
        m.swap_in_failures = 1;
        m.rescue_kept_s = 0.75;
        m.rescue_replay_s = 0.25;
        m.fault_downtime_s = 0.5;
        m.fault_recoveries = 2;
        m.migrations = 2;
        m.affine_routes = 5;
        m.swap_overlapped_s = 0.075;
        m.swap_stalled_s = 0.05;
        m.attrib.record(
            0.125,
            &crate::obsv::PhaseLedger {
                prefill_s: 0.25,
                decode_s: 0.5,
                stall_s: 0.0625,
                replay_s: 0.03125,
            },
        );
        let s = m.render();
        assert!(s.contains("requests=1"));
        assert!(s.contains("simulated device time"));
        assert!(s.contains("tok/J"));
        assert!(s.contains("evicted=3"), "{s}");
        assert!(s.contains("resumed=2"), "{s}");
        assert!(s.contains("wasted_sim=0.5000s"), "{s}");
        assert!(s.contains("steals=4"), "{s}");
        assert!(s.contains("aged=1"), "{s}");
        assert!(s.contains("hits=6 misses=2 (75%)"), "{s}");
        assert!(s.contains("cow=1"), "{s}");
        assert!(s.contains("saved_sim=0.2500s"), "{s}");
        assert!(s.contains("out=2 in=2 3.0 MiB"), "{s}");
        assert!(s.contains("saved_sim=1.5000s"), "{s}");
        assert!(s.contains("rescued=2 lost=1 retries=3 deadline_miss=2"), "{s}");
        assert!(s.contains("degraded=4 swapfail=1"), "{s}");
        assert!(s.contains("kept=0.7500s replayed=0.2500s"), "{s}");
        assert!(s.contains("mttr=250.0ms"), "{s}");
        assert!(s.contains("affine_routes=5"), "{s}");
        assert!(s.contains("resurrected=4 reclaimed=2 cached=2.0 MiB"), "{s}");
        assert!(s.contains("saved_resurrected_sim=0.1250s"), "{s}");
        assert!(s.contains("migrations=2 deferred=3"), "{s}");
        assert!(s.contains("hidden=0.0750s stalled=0.0500s"), "{s}");
        assert!(
            s.contains(
                "attrib: queue=0.1250s prefill=0.2500s decode=0.5000s \
                 stall=0.0625s replay=0.0312s"
            ),
            "{s}"
        );
        assert!(s.contains("p99.9="), "{s}");
    }

    #[test]
    fn p999_renders_and_reaches_the_extreme_tail() {
        // 499 fast samples and one 10 s straggler: the nearest-rank p99
        // stays fast while p99.9 must surface the straggler
        // (round(499·0.999) = 499, the last sorted index).
        let mut m = Metrics::new();
        for _ in 0..499 {
            m.record_response(0.010, 1, true);
        }
        m.record_response(10.0, 1, true);
        assert!(m.latency_pct(0.99).unwrap() < 0.02);
        assert!(m.latency_pct(0.999).unwrap() >= 9.0, "p99.9 sees the straggler");
        let s = m.render();
        assert!(s.contains("p99.9=10000.0ms"), "{s}");
    }

    #[test]
    fn slo_attainment_rolls_up_and_renders() {
        let mut m = Metrics::new();
        assert_eq!(m.slo_attainment(), None, "no contracted traffic: undefined, not 100%");
        assert!(m.render().contains("slo: eligible=0 met=0 attainment=- admission_sheds=0"));
        m.record_slo(true);
        m.record_slo(true);
        m.record_slo(false);
        m.admission_sheds = 2;
        assert!((m.slo_attainment().unwrap() - 2.0 / 3.0).abs() < 1e-12);
        let s = m.render();
        assert!(s.contains("slo: eligible=3 met=2 attainment=66.7% admission_sheds=2"), "{s}");
        // merge sums numerator, denominator, and sheds across nodes
        let mut other = Metrics::new();
        other.record_slo(true);
        other.admission_sheds = 3;
        m.merge(&other);
        assert_eq!((m.slo_eligible, m.slo_met, m.admission_sheds), (4, 3, 5));
        assert!((m.slo_attainment().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mttr_reads_none_until_a_recovery_closes() {
        let mut m = Metrics::new();
        assert_eq!(m.mttr_s(), None);
        let rendered = m.render();
        assert!(rendered.contains("mttr=-"), "{rendered}");
        m.fault_downtime_s = 1.0;
        m.fault_recoveries = 4;
        assert!((m.mttr_s().unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_fault_counters() {
        let mut a = Metrics::new();
        a.rescued_seqs = 1;
        a.retries = 2;
        a.rescue_kept_s = 0.5;
        a.fault_downtime_s = 1.0;
        a.fault_recoveries = 1;
        let mut b = Metrics::new();
        b.rescued_seqs = 3;
        b.lost_seqs = 1;
        b.deadline_misses = 2;
        b.degrade_events = 5;
        b.swap_in_failures = 2;
        b.rescue_replay_s = 0.25;
        b.fault_downtime_s = 3.0;
        b.fault_recoveries = 1;
        b.migrations = 3;
        b.affine_routes = 7;
        b.swap_overlapped_s = 0.5;
        b.swap_stalled_s = 0.25;
        a.merge(&b);
        assert_eq!(a.migrations, 3);
        assert_eq!(a.affine_routes, 7);
        assert!((a.swap_overlapped_s - 0.5).abs() < 1e-12);
        assert!((a.swap_stalled_s - 0.25).abs() < 1e-12);
        assert_eq!(a.rescued_seqs, 4);
        assert_eq!(a.lost_seqs, 1);
        assert_eq!(a.retries, 2);
        assert_eq!(a.deadline_misses, 2);
        assert_eq!(a.degrade_events, 5);
        assert_eq!(a.swap_in_failures, 2);
        assert!((a.rescue_kept_s - 0.5).abs() < 1e-12);
        assert!((a.rescue_replay_s - 0.25).abs() < 1e-12);
        assert!((a.mttr_s().unwrap() - 2.0).abs() < 1e-12, "4s over 2 recoveries");
    }

    #[test]
    fn prefix_sync_and_hit_rate() {
        let mut m = Metrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no admissions is not a hit");
        m.sync_prefix(crate::coordinator::kv::PrefixStats {
            hit_blocks: 30,
            miss_blocks: 10,
            cow_copies: 3,
            resurrected_blocks: 12,
            reclaimed_blocks: 4,
        });
        m.sync_cache(2048);
        assert_eq!(m.prefix_hits, 30);
        assert_eq!(m.prefix_misses, 10);
        assert_eq!(m.cow_copies, 3);
        assert_eq!(m.resurrected_blocks, 12);
        assert_eq!(m.reclaimed_blocks, 4);
        assert_eq!(m.cached_bytes, 2048);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        // sync overwrites (the pager snapshot is cumulative)…
        m.sync_prefix(crate::coordinator::kv::PrefixStats {
            hit_blocks: 40,
            miss_blocks: 12,
            cow_copies: 3,
            resurrected_blocks: 15,
            reclaimed_blocks: 4,
        });
        m.sync_cache(1024);
        assert_eq!(m.prefix_hits, 40);
        assert_eq!(m.resurrected_blocks, 15);
        assert_eq!(m.cached_bytes, 1024, "gauge overwrites, never accumulates");
        // …while merge sums across nodes
        let mut other = Metrics::new();
        other.prefix_hits = 5;
        other.prefix_misses = 8;
        other.cow_copies = 1;
        other.resurrected_blocks = 2;
        other.reclaimed_blocks = 1;
        other.cached_bytes = 512;
        other.saved_prefill_s = 0.5;
        other.saved_prefill_resurrected_s = 0.125;
        other.swap_outs = 7;
        other.swap_ins = 6;
        other.swap_bytes = 1024;
        other.swap_transfer_s = 0.25;
        other.saved_recompute_s = 2.0;
        m.merge(&other);
        assert_eq!(m.prefix_hits, 45);
        assert_eq!(m.prefix_misses, 20);
        assert_eq!(m.cow_copies, 4);
        assert_eq!(m.resurrected_blocks, 17);
        assert_eq!(m.reclaimed_blocks, 5);
        assert_eq!(m.cached_bytes, 1536, "fleet cached bytes sum across nodes");
        assert!((m.saved_prefill_s - 0.5).abs() < 1e-12);
        assert!((m.saved_prefill_resurrected_s - 0.125).abs() < 1e-12);
        assert_eq!(m.swap_outs, 7);
        assert_eq!(m.swap_ins, 6);
        assert_eq!(m.swap_bytes, 1024);
        assert!((m.swap_transfer_s - 0.25).abs() < 1e-12);
        assert!((m.saved_recompute_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_preemption_counters() {
        let mut a = Metrics::new();
        a.preemptions = 2;
        a.resumes = 1;
        a.wasted_prefill_s = 0.25;
        a.steals = 1;
        a.aged_promotions = 2;
        let mut b = Metrics::new();
        b.preemptions = 3;
        b.resumes = 3;
        b.wasted_prefill_s = 0.5;
        b.steals = 4;
        b.aged_promotions = 1;
        a.merge(&b);
        assert_eq!(a.preemptions, 5);
        assert_eq!(a.resumes, 4);
        assert!((a.wasted_prefill_s - 0.75).abs() < 1e-12);
        assert_eq!(a.steals, 5);
        assert_eq!(a.aged_promotions, 3);
    }

    #[test]
    fn prop_cached_sort_matches_sort_per_call() {
        // Percentiles read through the lazily rebuilt cache must equal the
        // old clone-and-sort implementation for arbitrary arrival orders,
        // including reads interleaved with appends.
        forall(0x1A7E, 200, |rng: &mut Rng| {
            let mut m = Metrics::new();
            let mut reference: Vec<f64> = Vec::new();
            for _ in 0..rng.range(1, 60) {
                let v = rng.f64_range(0.0, 10.0);
                m.record_response(v, 1, true);
                reference.push(v);
                if rng.chance(0.2) {
                    // interleaved read: forces rebuild-then-append cycles
                    let _ = m.latency_pct(0.5);
                }
            }
            reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &p in &[0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let idx = ((reference.len() as f64 - 1.0) * p).round() as usize;
                assert_eq!(m.latency_pct(p).unwrap().to_bits(), reference[idx].to_bits());
            }
            let mean = reference.iter().sum::<f64>() / reference.len() as f64;
            assert!((m.mean_latency().unwrap() - mean).abs() < 1e-9);
        });
    }

    #[test]
    fn prop_merge_equals_recording_into_one() {
        // Splitting a stream across two nodes and merging must yield the
        // same percentiles and counters as one combined stream.
        forall(0x4E46E, 100, |rng: &mut Rng| {
            let mut a = Metrics::new();
            let mut b = Metrics::new();
            let mut combined = Metrics::new();
            for _ in 0..rng.range(0, 40) {
                let v = rng.f64_range(0.0, 5.0);
                let tokens = rng.range(0, 9) as usize;
                let ok = rng.chance(0.9);
                let target = if rng.chance(0.5) { &mut a } else { &mut b };
                target.record_response(v, tokens, ok);
                combined.record_response(v, tokens, ok);
            }
            a.merge(&b);
            assert_eq!(a.requests, combined.requests);
            assert_eq!(a.errors, combined.errors);
            assert_eq!(a.tokens_out, combined.tokens_out);
            for &p in &[0.0, 0.5, 0.99, 1.0] {
                assert_eq!(
                    a.latency_pct(p).map(f64::to_bits),
                    combined.latency_pct(p).map(f64::to_bits)
                );
            }
        });
    }

    #[test]
    fn fleet_metrics_aggregate_and_sum_rates() {
        let mut n0 = Metrics::new();
        n0.tokens_out = 100;
        n0.simulated_device_s = 2.0; // 50 tok/s
        n0.simulated_energy_j = 50.0;
        n0.requests = 4;
        let mut n1 = Metrics::new();
        n1.tokens_out = 30;
        n1.simulated_device_s = 1.0; // 30 tok/s
        n1.simulated_energy_j = 30.0;
        n1.requests = 2;
        let fm = FleetMetrics { nodes: vec![("a", n0), ("b", n1)], tenants: Vec::new() };
        assert!((fm.sim_tokens_per_sec() - 80.0).abs() < 1e-12);
        let total = fm.total();
        assert_eq!(total.requests, 6);
        assert_eq!(total.tokens_out, 130);
        assert!((fm.sim_tokens_per_joule() - 130.0 / 80.0).abs() < 1e-12);
        assert!(fm.render().contains("fleet (2 nodes)"));
    }

    #[test]
    fn fleet_merge_percentiles_over_skewed_node_distributions() {
        // Node A serves a tight cluster of fast requests; node B a few
        // slow stragglers. The fleet total's percentiles must come from
        // the *combined* distribution, not any per-node shortcut — p50
        // sits in A's cluster while p99 must reach into B's tail.
        let mut a = Metrics::new();
        for i in 0..96 {
            a.record_response(0.010 + (i as f64) * 1e-5, 4, true);
        }
        let mut b = Metrics::new();
        for i in 0..4 {
            b.record_response(1.0 + i as f64, 4, true);
        }
        let fm = FleetMetrics {
            nodes: vec![("fast", a.clone()), ("slow", b.clone())],
            tenants: Vec::new(),
        };
        let total = fm.total();
        assert_eq!(total.requests, 100);
        // reference: one stream with the same 100 samples
        let mut combined = Metrics::new();
        for i in 0..96 {
            combined.record_response(0.010 + (i as f64) * 1e-5, 4, true);
        }
        for i in 0..4 {
            combined.record_response(1.0 + i as f64, 4, true);
        }
        for &p in &[0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(
                total.latency_pct(p).map(f64::to_bits),
                combined.latency_pct(p).map(f64::to_bits),
                "p{p}"
            );
        }
        assert!(total.latency_pct(0.5).unwrap() < 0.02, "p50 lives in the fast cluster");
        assert!(total.latency_pct(0.99).unwrap() >= 1.0, "p99 reaches the slow tail");
        // merging in the other order gives identical percentiles
        let swapped = FleetMetrics { nodes: vec![("slow", b), ("fast", a)], tenants: Vec::new() };
        assert_eq!(
            swapped.total().latency_pct(0.99).map(f64::to_bits),
            total.latency_pct(0.99).map(f64::to_bits)
        );
    }

    #[test]
    fn fleet_merge_attribution_over_skewed_node_distributions() {
        // Node A retires many queue-bound requests, node B a few
        // replay-heavy rescues. The fleet rollup must be the exact sum of
        // both nodes' phase seconds — order-independent, no averaging —
        // and show up in the rendered node and fleet lines.
        use crate::obsv::PhaseLedger;
        let mut a = Metrics::new();
        for _ in 0..10 {
            a.attrib.record(
                0.4,
                &PhaseLedger { prefill_s: 0.01, decode_s: 0.05, ..PhaseLedger::default() },
            );
        }
        let mut b = Metrics::new();
        for _ in 0..2 {
            b.attrib.record(
                0.01,
                &PhaseLedger {
                    prefill_s: 0.02,
                    decode_s: 0.1,
                    stall_s: 0.3,
                    replay_s: 1.5,
                },
            );
        }
        let fm = FleetMetrics {
            nodes: vec![("queuey", a.clone()), ("replayy", b.clone())],
            tenants: Vec::new(),
        };
        let total = fm.total();
        assert!((total.attrib.queue_s - (10.0 * 0.4 + 2.0 * 0.01)).abs() < 1e-9);
        assert!((total.attrib.prefill_s - (10.0 * 0.01 + 2.0 * 0.02)).abs() < 1e-9);
        assert!((total.attrib.decode_s - (10.0 * 0.05 + 2.0 * 0.1)).abs() < 1e-9);
        assert!((total.attrib.stall_s - 0.6).abs() < 1e-9);
        assert!((total.attrib.replay_s - 3.0).abs() < 1e-9);
        assert!(
            (total.attrib.total_s() - (a.attrib.total_s() + b.attrib.total_s())).abs() < 1e-9
        );
        // order-independent
        let swapped = FleetMetrics { nodes: vec![("replayy", b), ("fast", a)], tenants: vec![] };
        assert!((swapped.total().attrib.total_s() - total.attrib.total_s()).abs() < 1e-12);
        let s = fm.render();
        assert!(s.contains("attrib q=4.000"), "queuey's node line: {s}");
        assert!(s.contains("rp=3.000"), "replayy's node line shows the replay skew: {s}");
        assert!(s.contains("attrib: queue=4.0200s"), "fleet aggregate sums both: {s}");
    }

    #[test]
    fn fleet_merge_tokens_per_joule_over_skewed_nodes() {
        // tokens/J must be ratio-of-sums, not a mean of per-node ratios:
        // an efficient busy card and an inefficient idle one.
        let mut eff = Metrics::new();
        eff.tokens_out = 900;
        eff.simulated_energy_j = 300.0; // 3.0 tok/J
        eff.simulated_device_s = 9.0;
        let mut waste = Metrics::new();
        waste.tokens_out = 100;
        waste.simulated_energy_j = 700.0; // 0.143 tok/J
        waste.simulated_device_s = 1.0;
        let fm = FleetMetrics { nodes: vec![("eff", eff), ("waste", waste)], tenants: Vec::new() };
        let got = fm.sim_tokens_per_joule();
        assert!((got - 1000.0 / 1000.0).abs() < 1e-12, "{got}");
        let naive_mean = (3.0 + 100.0 / 700.0) / 2.0;
        assert!((got - naive_mean).abs() > 0.5, "must not be the mean of ratios");
        // a node that served nothing changes neither number
        let with_idle = FleetMetrics {
            nodes: {
                let mut n = fm.nodes.clone();
                n.push(("idle", Metrics::new()));
                n
            },
            tenants: Vec::new(),
        };
        assert!((with_idle.sim_tokens_per_joule() - got).abs() < 1e-12);
    }

    #[test]
    fn tenant_rollups_render_and_jain_behaves() {
        let mut light = Metrics::new();
        light.record_response(0.1, 40, true);
        let mut heavy = Metrics::new();
        heavy.record_response(0.9, 400, true);
        let fm = FleetMetrics {
            nodes: vec![("node", Metrics::new())],
            tenants: vec![("light".into(), light), ("heavy".into(), heavy)],
        };
        let s = fm.render();
        assert!(s.contains("tenant light"), "{s}");
        assert!(s.contains("tenant heavy"), "{s}");
        // jain: equal shares are perfectly fair, a 10× skew is not
        assert!((jain_index(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skewed = jain_index(&[40.0, 400.0]);
        assert!(skewed < 0.7, "{skewed}");
        assert!(jain_index(&[0.0, 0.0]) == 1.0, "no service is not unfair");
        assert!((jain_index(&[5.0]) - 1.0).abs() < 1e-12);
        let n4 = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((n4 - 0.25).abs() < 1e-12, "one-of-four monopoly → 1/n");
    }
}
