//! Calibrated device catalogue.
//!
//! Entries:
//! - [`cmp170hx`] — the paper's subject (Tables 2-1…2-5);
//! - [`a100_pcie`] — the healthy-silicon reference used for every
//!   "theoretical performance" overlay in §4;
//! - the rest of the CMP family (30/40/50/90HX, Table 1-1) for the market
//!   model — modeled at family-level fidelity (headline FP16 TFLOPS and
//!   price), not SM-accurate;
//! - historical comparison cards from §3.1 (Tesla C870, Tesla P6).

use super::rates::IssueRates;
use super::spec::DeviceSpec;
use super::throttle::ThrottleProfile;
use crate::memhier::hbm::MemorySystem;
use crate::memhier::pcie::{PcieGen, PcieLink};
use crate::power::PowerModel;

/// NVIDIA CMP 170HX 8GB (GA100-105F-A1). Tables 2-1…2-4.
pub fn cmp170hx() -> DeviceSpec {
    DeviceSpec {
        name: "CMP 170HX",
        arch: "Ampere (GA100-105F-A1)",
        sms: 70,
        cuda_cores: 4480,
        base_clock_hz: 1.140e9,
        boost_clock_hz: 1.410e9,
        rates: IssueRates::ga100(),
        throttle: ThrottleProfile::cmp170hx_limiter(),
        mem: MemorySystem::cmp170hx_hbm2e(),
        pcie: PcieLink::cmp170hx_stock(),
        power: PowerModel::ga100(),
        tdp_w: 250.0,
        l1_bytes_per_sm: 192 * 1024,
        price_usd: 4500.0, // Table 1-2 estimated ASP
        released: "2021 Q3",
    }
}

/// NVIDIA A100 40GB PCIe — the paper's theoretical-performance reference
/// (108 SMs, 1555 GB/s, 250 W PCIe TDP).
pub fn a100_pcie() -> DeviceSpec {
    DeviceSpec {
        name: "A100 40GB PCIe",
        arch: "Ampere (GA100)",
        sms: 108,
        cuda_cores: 6912,
        base_clock_hz: 0.765e9,
        boost_clock_hz: 1.410e9,
        rates: IssueRates::ga100(),
        throttle: ThrottleProfile::native(),
        mem: MemorySystem::a100_hbm2e(),
        pcie: PcieLink::new(PcieGen::Gen4, 16),
        power: PowerModel::ga100(),
        tdp_w: 250.0,
        l1_bytes_per_sm: 192 * 1024,
        price_usd: 10_000.0,
        released: "2020 Q2",
    }
}

/// CMP 170HX with the Ex.2.2 x16 capacitor mod applied.
pub fn cmp170hx_x16() -> DeviceSpec {
    let mut d = cmp170hx();
    d.name = "CMP 170HX (x16 mod)";
    d.pcie = PcieLink::cmp170hx_x16_mod();
    d
}

// --- CMP family (market-model fidelity: headline FP16 TFLOPS + price). ---
// Table 1-1. Turing-class silicon; SM counts/clocks chosen to reproduce the
// table's FP16 TFLOPS with the legacy rate model (half2 = 2× fp32 rate on
// Turing, expressed via cores_per_sm scaling).

fn cmp_family(
    name: &'static str,
    sms: u32,
    cores: u32,
    boost_ghz: f64,
    mem: MemorySystem,
    tdp: f64,
    price: f64,
    released: &'static str,
) -> DeviceSpec {
    let cores_per_sm = cores as f64 / sms as f64;
    let mut rates = IssueRates::legacy(cores_per_sm);
    // Turing/Ampere consumer: packed-half at 2× fp32 rate.
    rates.half2 = cores_per_sm; // HFMA2 @ core rate → 4 flops = 2× fp32 flops
    rates.half_scalar = cores_per_sm / 2.0;
    rates.dp4a = cores_per_sm / 2.0;
    DeviceSpec {
        name,
        arch: "Turing/Ampere (CMP family)",
        sms,
        cuda_cores: cores,
        base_clock_hz: boost_ghz * 0.8e9,
        boost_clock_hz: boost_ghz * 1e9,
        rates,
        throttle: ThrottleProfile::cmp170hx_limiter(),
        mem,
        pcie: PcieLink::new(PcieGen::Gen1, 4),
        power: PowerModel::ga100(),
        tdp_w: tdp,
        l1_bytes_per_sm: 96 * 1024,
        price_usd: price,
        released,
    }
}

/// CMP 30HX (TU116-class): 10.05 FP16 TFLOPS, ~$750.
pub fn cmp30hx() -> DeviceSpec {
    cmp_family("CMP 30HX", 22, 1408, 1.785, MemorySystem::gddr6(6, 336.0), 125.0, 750.0, "2021 Q1")
}

/// CMP 40HX (TU106-class): 15.21 FP16 TFLOPS, ~$650.
pub fn cmp40hx() -> DeviceSpec {
    cmp_family("CMP 40HX", 36, 2304, 1.65, MemorySystem::gddr6(8, 448.0), 185.0, 650.0, "2021 Q1")
}

/// CMP 50HX (TU102-class): 22.15 FP16 TFLOPS, ~$800.
pub fn cmp50hx() -> DeviceSpec {
    cmp_family("CMP 50HX", 56, 3584, 1.545, MemorySystem::gddr6(10, 560.0), 250.0, 800.0, "2021 Q2")
}

/// CMP 90HX (GA102-class): 21.89 FP16 TFLOPS, ~$1550. Ampere consumer
/// silicon runs packed-half at the FP32 rate (not Turing's 2×), so the
/// half2 issue rate is halved relative to the family template.
pub fn cmp90hx() -> DeviceSpec {
    let mem = MemorySystem::gddr6(10, 760.0);
    let mut d = cmp_family("CMP 90HX", 50, 6400, 1.71, mem, 250.0, 1550.0, "2021 Q2");
    d.rates.half2 /= 2.0;
    d
}

// --- Historical comparison cards (§3.1). ---

/// Tesla C870 (G80, 2007): ~0.346 TFLOPS FP32 — the only card the crippled
/// CMP 170HX beats at default settings.
pub fn tesla_c870() -> DeviceSpec {
    DeviceSpec {
        name: "Tesla C870",
        arch: "Tesla (G80)",
        sms: 16,
        cuda_cores: 128,
        base_clock_hz: 1.35e9,
        boost_clock_hz: 1.35e9,
        rates: IssueRates::legacy(8.0),
        throttle: ThrottleProfile::native(),
        mem: MemorySystem::gddr6(2, 77.0),
        pcie: PcieLink::new(PcieGen::Gen1, 16),
        power: PowerModel::pascal(),
        tdp_w: 171.0,
        l1_bytes_per_sm: 16 * 1024,
        price_usd: 1299.0,
        released: "2007 Q2",
    }
}

/// Tesla P6 (GP104 mobile, 2017): ~6.2 TFLOPS FP32 — the card the
/// FMA-restored CMP 170HX matches (§3.1).
pub fn tesla_p6() -> DeviceSpec {
    DeviceSpec {
        name: "Tesla P6",
        arch: "Pascal (GP104)",
        sms: 16,
        cuda_cores: 2048,
        base_clock_hz: 1.012e9,
        boost_clock_hz: 1.506e9,
        rates: IssueRates::legacy(128.0),
        throttle: ThrottleProfile::native(),
        mem: MemorySystem::gddr6(16, 192.0),
        pcie: PcieLink::new(PcieGen::Gen3, 16),
        power: PowerModel::pascal(),
        tdp_w: 90.0,
        l1_bytes_per_sm: 48 * 1024,
        price_usd: 2000.0,
        released: "2017 Q1",
    }
}

/// All registry entries, for `cmphx specs` and the market model.
pub fn all() -> Vec<DeviceSpec> {
    vec![
        cmp170hx(),
        cmp170hx_x16(),
        a100_pcie(),
        cmp30hx(),
        cmp40hx(),
        cmp50hx(),
        cmp90hx(),
        tesla_c870(),
        tesla_p6(),
    ]
}

/// Look up a device by (case-insensitive) name fragment.
pub fn by_name(name: &str) -> Option<DeviceSpec> {
    let lower = name.to_lowercase();
    all().into_iter()
        .find(|d| d.name.to_lowercase().contains(&lower))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn cmp170hx_core_counts_match_table_2_2() {
        let d = cmp170hx();
        assert_eq!(d.sms, 70);
        assert_eq!(d.cuda_cores, 4480);
        assert_eq!(d.cuda_cores / d.sms, 64);
    }

    #[test]
    fn cmp_family_fp16_matches_table_1_1() {
        // Table 1-1 FP16 TFLOPS: 30HX 10.05, 40HX 15.21, 50HX 22.15, 90HX 21.89.
        assert_close(cmp30hx().fp16_tflops(), 10.05, 0.02);
        assert_close(cmp40hx().fp16_tflops(), 15.21, 0.02);
        assert_close(cmp50hx().fp16_tflops(), 22.15, 0.02);
        assert_close(cmp90hx().fp16_tflops(), 21.89, 0.02);
    }

    #[test]
    fn c870_is_the_only_card_below_crippled_cmp() {
        // §3.1: crippled FP32 ≈ 0.39 "surpasses only the Tesla C870 (0.346)".
        let c870 = tesla_c870();
        assert_close(c870.fp32_tflops(), 0.346, 0.01);
    }

    #[test]
    fn p6_matches_restored_cmp() {
        // §3.1: restored ≈6.2 TFLOPS "surpasses the Tesla P6".
        let p6 = tesla_p6();
        assert!(p6.fp32_tflops() > 5.9 && p6.fp32_tflops() < 6.3, "{}", p6.fp32_tflops());
    }

    #[test]
    fn lookup_by_fragment() {
        assert!(by_name("170hx").is_some());
        assert!(by_name("A100").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn sm_ratio_is_the_papers_prefill_scaler() {
        // §4.2: u_d = u_o × d_sm / o_sm with 70/108.
        let ratio = cmp170hx().sms as f64 / a100_pcie().sms as f64;
        assert_close(ratio, 70.0 / 108.0, 1e-12);
    }

    #[test]
    fn all_devices_have_positive_specs() {
        for d in all() {
            assert!(d.sms > 0 && d.boost_clock_hz > 0.0 && d.tdp_w > 0.0, "{}", d.name);
            assert!(d.mem.peak_bw > 0.0 && d.price_usd > 0.0);
        }
    }
}
