//! Lower-once kernel cache.
//!
//! [`LoweredKernel`] captures everything [`crate::sim::engine`] needs from a
//! [`Kernel`] that does **not** depend on the device or [`SimConfig`]: the
//! flat instruction mix, the launch geometry, the traffic split into
//! HBM/L2 bytes, and the energy-weighted op count for the power model.
//! Lowering walks the kernel IR exactly once; every subsequent
//! [`crate::sim::simulate_lowered`] call — across devices, throttle
//! profiles, and engine configs — reuses the cached form. This is the
//! per-sweep contract the bench ports, `llm::llamabench`, the report
//! figures, and the coordinator fleet all rely on: *lower once, simulate
//! many*.
//!
//! [`SimConfig`]: crate::sim::SimConfig

use crate::isa::ir::{Kernel, Traffic};
use crate::isa::mix::InstMix;

/// A kernel lowered to the device-independent form the timing engine
/// consumes. Build one with [`LoweredKernel::lower`] and hand it to
/// [`crate::sim::simulate_lowered`] or [`crate::sim::batch`].
#[derive(Clone, Debug)]
pub struct LoweredKernel {
    pub name: String,
    /// Whole-grid instruction mix (IR walked exactly once).
    pub mix: InstMix,
    /// The original traffic descriptor (kept for callers that inspect it).
    pub traffic: Traffic,
    /// Total threads in the grid.
    pub threads: u64,
    /// Threads per block (occupancy quantization input).
    pub block: u32,
    /// Blocks in the grid (threads ⌈/⌉ block).
    pub blocks: u64,
    /// Bytes that miss L2 and hit HBM (reads × miss rate + all writes).
    pub hbm_bytes: f64,
    /// Read bytes served from L2.
    pub l2_bytes: f64,
    /// Energy-weighted op count for the power model:
    /// Σ count × (flops + iops) × energy_weight per class.
    pub energy_ops: f64,
}

impl LoweredKernel {
    /// Lower a kernel: one IR walk + one pass over the (fixed-size) mix.
    pub fn lower(kernel: &Kernel) -> Self {
        let mix = InstMix::from_kernel(kernel);
        let hit = kernel.traffic.l2_hit_rate.clamp(0.0, 1.0);
        let read = kernel.traffic.read_bytes as f64;
        let hbm_bytes = read * (1.0 - hit) + kernel.traffic.write_bytes as f64;
        let l2_bytes = read * hit;
        let energy_ops: f64 = mix
            .iter()
            .map(|(c, n)| n as f64 * (c.flops() + c.iops()) as f64 * c.energy_weight())
            .sum();
        LoweredKernel {
            name: kernel.name.clone(),
            mix,
            traffic: kernel.traffic,
            threads: kernel.threads,
            block: kernel.block,
            blocks: kernel.blocks(),
            hbm_bytes,
            l2_bytes,
            energy_ops,
        }
    }

    /// Total bytes that move through the memory system (HBM + L2).
    pub fn bytes(&self) -> f64 {
        self.hbm_bytes + self.l2_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;
    use crate::isa::ir::{MemPattern, Stmt};
    use crate::testutil::assert_close;

    fn kernel() -> Kernel {
        Kernel::new("k", 1000, 256)
            .with_body(vec![Stmt::looped(4, vec![Stmt::op(Ffma, 2)]), Stmt::op(Stg, 1)])
            .with_traffic(Traffic {
                read_bytes: 1_000_000,
                write_bytes: 500_000,
                pattern: MemPattern::Coalesced,
                l2_hit_rate: 0.25,
            })
    }

    #[test]
    fn lowering_caches_mix_and_geometry() {
        let k = kernel();
        let lk = LoweredKernel::lower(&k);
        assert_eq!(lk.mix, InstMix::from_kernel(&k));
        assert_eq!(lk.blocks, k.blocks());
        assert_eq!(lk.threads, k.threads);
        assert_eq!(lk.block, k.block);
        assert_eq!(lk.name, k.name);
    }

    #[test]
    fn traffic_split_respects_hit_rate() {
        let lk = LoweredKernel::lower(&kernel());
        assert_close(lk.l2_bytes, 250_000.0, 1e-12);
        assert_close(lk.hbm_bytes, 750_000.0 + 500_000.0, 1e-12);
        assert_close(lk.bytes(), 1_500_000.0, 1e-12);
    }

    #[test]
    fn energy_ops_matches_direct_sum() {
        let lk = LoweredKernel::lower(&kernel());
        // 8000 FFMA × 2 flops × weight 1.0 (Stg contributes nothing).
        assert_close(lk.energy_ops, 8000.0 * 2.0, 1e-12);
    }
}
