//! Seed-driven fault scripts.
//!
//! A [`FaultPlan`] is data, not behaviour: a list of [`FaultEvent`]s, each
//! pinning a [`FaultKind`] to a (node, engine-round) coordinate. Scripts
//! come from two places — hand-written (the chaos suite's "kill card 1 at
//! round 3" scenarios) or generated from a seed with [`FaultPlan::seeded`]
//! (the CI smoke matrix). Both are pure values: replaying the same script
//! against the same workload reproduces the same failure, which is what
//! makes a chaos regression debuggable by seed.

use crate::testutil::Rng;

/// One injectable failure mode. The mix mirrors how salvage mining cards
/// actually die in service: outright (power/riser), intermittently
/// (driver wedge, thermal governor), or partially (lanes renegotiated
/// down, VRAM pages gone bad, host staging corrupted).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// The card drops off the bus mid-decode. Terminal for the node; its
    /// in-flight sequences are rescue candidates.
    NodeDeath,
    /// The worker wedges for `rounds` engine rounds, then recovers.
    TransientStall { rounds: u64 },
    /// The riser renegotiates the link down to `lanes` (x16 → x1 style).
    LinkDowngrade { lanes: u32 },
    /// `blocks` KV blocks are lost to bad VRAM pages, permanently.
    VramPageLoss { blocks: usize },
    /// The next swap-in from the host pool finds corrupt pages and fails.
    SwapInFailure,
    /// The thermal governor slows decode by `factor`× for `rounds` rounds.
    ThermalThrottle { factor: f64, rounds: u64 },
}

impl FaultKind {
    /// Stable lowercase name — what the trace journal's `fault` spans
    /// carry ([`crate::obsv::SpanKind::Fault`]).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeDeath => "node_death",
            FaultKind::TransientStall { .. } => "transient_stall",
            FaultKind::LinkDowngrade { .. } => "link_downgrade",
            FaultKind::VramPageLoss { .. } => "vram_page_loss",
            FaultKind::SwapInFailure => "swap_in_failure",
            FaultKind::ThermalThrottle { .. } => "thermal_throttle",
        }
    }
}

/// A [`FaultKind`] scheduled on a node's engine-round clock. Rounds are
/// the worker's own loop iterations — not wall time — so a script fires
/// at the same point in the computation regardless of host speed.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub node: usize,
    pub round: u64,
    pub kind: FaultKind,
}

/// An immutable fault script for one fleet run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A hand-written script (the chaos suite's targeted scenarios).
    pub fn script(events: Vec<FaultEvent>) -> Self {
        FaultPlan { events }
    }

    /// An empty plan: the injector becomes a no-op.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Generate a script from a seed: each of `nodes` cards draws a fault
    /// with probability `rate` per round over `rounds` rounds. Node
    /// deaths are capped at `nodes - 1` so a seeded run always keeps one
    /// survivor to rescue onto — the smoke matrix asserts zero lost
    /// responses, which is unsatisfiable with the whole fleet gone.
    pub fn seeded(seed: u64, nodes: usize, rounds: u64, rate: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        let mut deaths_left = nodes.saturating_sub(1);
        for node in 0..nodes {
            let mut dead = false;
            for round in 1..=rounds {
                if dead || !rng.chance(rate) {
                    continue;
                }
                let kind = match rng.below(10) {
                    0..=2 => FaultKind::TransientStall { rounds: rng.range(1, 4) },
                    3..=5 => FaultKind::ThermalThrottle {
                        factor: rng.f64_range(1.5, 4.0),
                        rounds: rng.range(2, 8),
                    },
                    6 => FaultKind::LinkDowngrade { lanes: if rng.chance(0.5) { 1 } else { 2 } },
                    7 => FaultKind::VramPageLoss { blocks: rng.range(1, 3) as usize },
                    8 => FaultKind::SwapInFailure,
                    _ if deaths_left > 0 => {
                        deaths_left -= 1;
                        dead = true;
                        FaultKind::NodeDeath
                    }
                    // death budget spent: degrade instead of killing
                    _ => FaultKind::TransientStall { rounds: rng.range(1, 4) },
                };
                events.push(FaultEvent { node, round, kind });
            }
        }
        FaultPlan { events }
    }

    /// The events scripted for `node`, in firing order.
    pub fn for_node(&self, node: usize) -> Vec<(u64, FaultKind)> {
        let mut out: Vec<(u64, FaultKind)> = self
            .events
            .iter()
            .filter(|e| e.node == node)
            .map(|e| (e.round, e.kind.clone()))
            .collect();
        out.sort_by_key(|&(round, _)| round);
        out
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_reproduces_the_same_script() {
        let a = FaultPlan::seeded(0xC0FFEE, 2, 64, 0.2);
        let b = FaultPlan::seeded(0xC0FFEE, 2, 64, 0.2);
        assert_eq!(a, b, "a chaos failure must be replayable by seed alone");
        assert!(!a.is_empty(), "a 20% rate over 128 node-rounds must draw something");
    }

    #[test]
    fn different_seeds_diverge() {
        let a = FaultPlan::seeded(1, 2, 64, 0.3);
        let b = FaultPlan::seeded(2, 2, 64, 0.3);
        assert_ne!(a, b);
    }

    #[test]
    fn seeded_plans_always_leave_a_survivor() {
        for seed in 0..50 {
            for nodes in 1..4usize {
                let plan = FaultPlan::seeded(seed, nodes, 128, 0.5);
                let deaths =
                    plan.events.iter().filter(|e| e.kind == FaultKind::NodeDeath).count();
                assert!(
                    deaths < nodes.max(1),
                    "seed {seed}: {deaths} deaths on a {nodes}-card fleet"
                );
            }
        }
    }

    #[test]
    fn a_dead_node_draws_no_further_events() {
        for seed in 0..50 {
            let plan = FaultPlan::seeded(seed, 3, 128, 0.5);
            for node in 0..3 {
                let script = plan.for_node(node);
                if let Some(pos) =
                    script.iter().position(|(_, k)| *k == FaultKind::NodeDeath)
                {
                    assert_eq!(
                        pos,
                        script.len() - 1,
                        "seed {seed}: events scripted after node {node}'s death"
                    );
                }
            }
        }
    }

    #[test]
    fn fault_kind_names_are_stable() {
        assert_eq!(FaultKind::NodeDeath.name(), "node_death");
        assert_eq!(FaultKind::TransientStall { rounds: 2 }.name(), "transient_stall");
        assert_eq!(FaultKind::LinkDowngrade { lanes: 1 }.name(), "link_downgrade");
        assert_eq!(FaultKind::VramPageLoss { blocks: 3 }.name(), "vram_page_loss");
        assert_eq!(FaultKind::SwapInFailure.name(), "swap_in_failure");
        assert_eq!(
            FaultKind::ThermalThrottle { factor: 2.0, rounds: 4 }.name(),
            "thermal_throttle"
        );
    }

    #[test]
    fn zero_rate_is_an_empty_plan() {
        assert!(FaultPlan::seeded(7, 2, 256, 0.0).is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn for_node_filters_and_sorts_by_round() {
        let plan = FaultPlan::script(vec![
            FaultEvent { node: 1, round: 9, kind: FaultKind::NodeDeath },
            FaultEvent { node: 0, round: 4, kind: FaultKind::SwapInFailure },
            FaultEvent {
                node: 1,
                round: 2,
                kind: FaultKind::TransientStall { rounds: 1 },
            },
        ]);
        let n1 = plan.for_node(1);
        assert_eq!(n1.len(), 2);
        assert_eq!(n1[0], (2, FaultKind::TransientStall { rounds: 1 }));
        assert_eq!(n1[1], (9, FaultKind::NodeDeath));
        assert_eq!(plan.for_node(2), vec![]);
    }
}
