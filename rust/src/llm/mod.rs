//! LLM inference performance model (§4 of the paper).
//!
//! Reproduces the llama-bench experiment: Qwen2.5-1.5B under the ggml
//! framework in six quantization formats (f32, f16, q8_0, q6_k, q4_k_m,
//! q2_k), measuring prefill (pp512, compute-bound), decode (tg128,
//! bandwidth-bound) and token/W — on the CMP 170HX at both fmad policies,
//! with the paper's A100-scaled theoretical overlays:
//!
//! - prefill theoretical: `u_d = u_o / o_sm · d_sm` (SM-count scaling)
//! - decode theoretical:  `u_d = u_o / o_bw · d_bw` (bandwidth scaling)
//!
//! The per-quant kernel decomposition mirrors llama.cpp's CUDA backend:
//! f32/f16 GEMMs dispatch to prebuilt cuBLAS ([`KernelSource::Lib`] — the
//! fmad flag cannot bite, so those models show no noFMA gains), while
//! quantized matmuls are JIT-compiled MMQ/MMVQ kernels mixing DP4A dot
//! products (uncrippled) with per-block float scale math (FFMA — crippled
//! by default, restored by `-fmad=false`). K-quants carry more scale math
//! per weight, which is why the noFMA speedup *grows* as quantization gets
//! more aggressive, peaking at Q2_K (231%, Graph 4-1).

pub mod ablations;
pub mod kernels;
pub mod llamabench;
pub mod model;
pub mod quant;

pub use llamabench::{BenchResult, LlamaBench};
pub use model::ModelDesc;
pub use quant::QuantFormat;
