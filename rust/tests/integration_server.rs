//! Integration: the serving coordinator end-to-end over real artifacts,
//! including failure injection (oversized requests, overload, cancels) and
//! the multi-card fleet engine under continuous batching.
//!
//! Every test skips (passes vacuously, with a note on stderr) when the
//! AOT artifacts are missing or PJRT is unavailable (the vendored stub xla
//! crate) — environments that cannot run the runtime at all.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{
    jain_index, FleetMetrics, NodeConfig, RoutePolicy, Server, ServerConfig, ServerHandle,
};
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::qos::TenantSpec;
mod common;
use common::artifact_dir;

fn config(max_batch: usize) -> ServerConfig {
    ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(20),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    }
}

/// The artifact runtime's prefill window, read from goldens.json — the
/// page-pressure tests pin their block budgets to it exactly.
fn artifact_prefill_t(dir: &cmphx::runtime::ArtifactDir) -> usize {
    cmphx::runtime::goldens::config_usize(dir, "prefill_t").unwrap()
}

fn start(cfg: ServerConfig) -> Option<ServerHandle> {
    Some(Server::start(artifact_dir()?, cfg).unwrap())
}

#[test]
fn serves_a_batch_of_requests_with_real_tokens() {
    let Some(server) = start(config(4)) else { return };
    let mut rxs = Vec::new();
    for i in 0..4 {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
        rxs.push(server.submit(prompt, 6).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(resp.simulated_device_s > 0.0, "overlay must accrue");
        assert_eq!(resp.node, 0, "single-node fleet serves on node 0");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 4);
    assert_eq!(m.errors, 0);
    assert_eq!(m.tokens_out, 24);
    assert!(m.simulated_device_s > 0.0);
    assert!(m.simulated_energy_j > 0.0, "energy overlay must accrue");
    assert!(m.mean_batch_size() >= 1.0);
}

#[test]
fn identical_prompts_get_identical_tokens() {
    // Determinism across the whole path: continuous batching must not leak
    // state between sequences.
    let Some(server) = start(config(3)) else { return };
    let prompt: Vec<i32> = vec![5, 9, 13, 2, 8, 1, 30, 44];
    let rx1 = server.submit(prompt.clone(), 5).unwrap();
    let rx2 = server.submit(prompt.clone(), 5).unwrap();
    let rx3 = server.submit(prompt, 5).unwrap();
    let a = rx1.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    let b = rx2.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    let c = rx3.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    assert_eq!(a, b);
    assert_eq!(b, c);
    drop(server);
}

#[test]
fn oversized_requests_are_rejected_not_crashed() {
    let Some(server) = start(config(2)) else { return };
    // prompt longer than the prefill window
    let rx = server.submit(vec![1; 64], 4).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(!resp.ok());
    assert!(resp.error.as_deref().unwrap().contains("window"));
    // generation longer than the KV budget
    let rx = server.submit(vec![1, 2, 3], 10_000).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(!resp.ok());
    // and the server still works afterwards
    let rx = server.submit(vec![1, 2, 3], 3).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().ok());
    let m = server.shutdown();
    assert_eq!(m.errors, 2);
}

#[test]
fn cancelled_requests_do_not_wedge_the_worker() {
    let Some(server) = start(config(2)) else { return };
    // drop the receiver immediately = cancel
    drop(server.submit(vec![1, 2, 3], 4).unwrap());
    // a live request right behind it must still be served
    let rx = server.submit(vec![4, 5, 6], 4).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(resp.ok());
    drop(server);
}

#[test]
fn shutdown_drains_outstanding_requests() {
    let Some(server) = start(config(4)) else { return };
    let rx = server.submit(vec![7, 7, 7], 4).unwrap();
    let metrics = server.shutdown(); // joins dispatcher + workers
    let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
    assert!(resp.ok(), "in-flight request must complete during shutdown");
    assert_eq!(metrics.requests, 1);
}

#[test]
fn scheduler_policies_serve_mixed_lengths() {
    for policy in [StepPolicy::RoundRobin, StepPolicy::ShortestFirst] {
        let mut cfg = config(3);
        cfg.step_policy = policy;
        let Some(server) = start(cfg) else { return };
        let rx_short = server.submit(vec![1, 2], 2).unwrap();
        let rx_long = server.submit(vec![3, 4], 8).unwrap();
        let short = rx_short.recv_timeout(Duration::from_secs(120)).unwrap();
        let long = rx_long.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(short.tokens.len(), 2, "{policy:?}");
        assert_eq!(long.tokens.len(), 8, "{policy:?}");
        drop(server);
    }
}

#[test]
fn late_arrivals_join_the_decode_round_in_flight() {
    // Continuous batching: while a long generation is in flight, a late
    // request must be admitted and finish well before the long one's
    // final token forces a full drain (the old window batcher would have
    // parked it in the next batch).
    let mut cfg = config(4);
    cfg.batch.max_wait = Duration::from_millis(1);
    let Some(server) = start(cfg) else { return };
    let rx_long = server.submit(vec![1, 2, 3, 4], 24).unwrap();
    // let the long request's round get going
    std::thread::sleep(Duration::from_millis(50));
    let rx_late = server.submit(vec![9, 8, 7], 2).unwrap();
    let late = rx_late.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(late.ok(), "{:?}", late.error);
    assert_eq!(late.tokens.len(), 2);
    let long = rx_long.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(long.ok());
    assert_eq!(long.tokens.len(), 24);
    let m = server.shutdown();
    assert_eq!(m.requests, 2);
    assert_eq!(m.errors, 0);
}

#[test]
fn preemption_prevents_starvation_under_page_pressure() {
    // The acceptance scenario: a long generation and a stream of short
    // requests share a page pool too small for both at the long one's
    // peak. The engine must preempt the long sequence (KV dropped,
    // recomputed on resume) so the shorts complete instead of starving —
    // and the replayed long sequence must produce the identical tokens a
    // pressure-free run produces.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    const LONG: usize = 24;
    const SHORT: usize = 6;
    // Enough pages for the long sequence alone at full length, and for a
    // short to join while the long is young — but not for both at peak.
    // (Tuned for the shipped artifacts' prefill_t = 16; the max() keeps a
    // short admissible for other geometries.)
    let budget = (prefill_t + LONG - 1).max(2 * prefill_t + 4);
    let long_prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];

    // Reference: the same long request served without page pressure.
    let Some(reference) = start(config(2)) else { return };
    let rx = reference.submit(long_prompt.clone(), LONG).unwrap();
    let expected_long = rx.recv_timeout(Duration::from_secs(240)).unwrap().tokens;
    drop(reference);

    let mut cfg = config(2);
    cfg.step_policy = StepPolicy::ShortestFirst;
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some(budget);
    let Some(server) = start(cfg) else { return };
    let rx_long = server.submit(long_prompt, LONG).unwrap();
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, SHORT).unwrap()
        })
        .collect();
    for rx in rx_shorts {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "short request starved: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), SHORT);
    }
    let long = rx_long.recv_timeout(Duration::from_secs(240)).unwrap();
    assert!(long.ok(), "{:?}", long.error);
    assert_eq!(
        long.tokens, expected_long,
        "resume must replay to the identical state"
    );
    assert!(long.preemptions >= 1, "the long sequence was never evicted");
    let m = server.shutdown();
    assert_eq!(m.errors, 0);
    assert!(m.preemptions >= 1, "page pressure must have preempted");
    assert!(m.resumes >= 1, "preempted work must have resumed");
    assert!(m.wasted_prefill_s > 0.0, "recompute must be accounted as waste");
}

#[test]
fn swap_preemption_restores_state_over_pcie_without_recompute() {
    // The same pressure scenario with `--swap` armed: by the time page
    // pressure evicts the long sequence it has several decode rounds of
    // replay, so its KV round trip (~1 MB over the 170HX's stock gen1 x4
    // link, a few ms simulated) is far cheaper than the overlay's
    // recompute estimate (decode replay at tens of ms/token) and the
    // chooser swaps it: the decode state parks in the host pool and
    // comes back verbatim — same tokens, swap ledger populated, every
    // swap-out matched by a swap-in.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    const LONG: usize = 24;
    let budget = (prefill_t + LONG - 1).max(2 * prefill_t + 4);
    let long_prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];

    let Some(reference) = start(config(2)) else { return };
    let rx = reference.submit(long_prompt.clone(), LONG).unwrap();
    let expected_long = rx.recv_timeout(Duration::from_secs(240)).unwrap().tokens;
    drop(reference);

    let mut cfg = config(2);
    cfg.step_policy = StepPolicy::ShortestFirst;
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some(budget);
    cfg.batch.swap = true;
    let Some(server) = start(cfg) else { return };
    let rx_long = server.submit(long_prompt, LONG).unwrap();
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, 6).unwrap()
        })
        .collect();
    for rx in rx_shorts {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "short request starved: {:?}", resp.error);
    }
    let long = rx_long.recv_timeout(Duration::from_secs(240)).unwrap();
    assert!(long.ok(), "{:?}", long.error);
    assert_eq!(long.tokens, expected_long, "restored state must continue identically");
    assert!(long.preemptions >= 1, "page pressure must have evicted the long one");
    assert!(long.swaps >= 1, "the eviction must have taken the swap path");
    assert!(long.swaps <= long.preemptions);
    let m = server.shutdown();
    assert_eq!(m.errors, 0);
    assert!(m.swap_outs >= 1, "swap-outs must be counted");
    assert_eq!(m.swap_ins, m.swap_outs, "everything parked must come back");
    assert!(m.resumes >= m.swap_ins, "swap-ins are resumes too");
    assert!(m.swap_bytes > 0 && m.swap_transfer_s > 0.0, "PCIe time must be charged");
    assert!(m.saved_recompute_s > 0.0, "the chooser's margin must be recorded");
}

#[test]
fn identical_prompts_share_prefix_blocks_at_admission() {
    // Three concurrent requests with the same prompt: the first admission
    // allocates the prefill window's blocks and registers their chain
    // hashes; the later ones pin those blocks instead of allocating, and
    // everyone still decodes the same tokens. (The cold-start gather
    // window keeps the batch concurrent, so the shared blocks are live
    // when the later admissions arrive.)
    let mut cfg = config(4);
    cfg.batch.max_wait = Duration::from_millis(200);
    let Some(server) = start(cfg) else { return };
    let prompt = vec![7, 7, 3, 2, 9, 1, 1, 5];
    let rxs: Vec<_> = (0..3).map(|_| server.submit(prompt.clone(), 6).unwrap()).collect();
    let mut outs = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        outs.push(resp.tokens);
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0], outs[2]);
    let m = server.shutdown();
    assert!(
        m.prefix_hits >= 1,
        "identical concurrent prompts must hit the prefix cache (hits={} misses={})",
        m.prefix_hits,
        m.prefix_misses
    );
    assert!(m.saved_prefill_s > 0.0, "cache hits must credit saved prefill");
}

#[test]
fn disabled_preemption_fails_overcommitted_sequences_cleanly() {
    // The same pressure with preemption off: there is no relief valve, so
    // once every live sequence stalls on page growth the engine keeps
    // liveness by failing the longest-remaining sequence terminally — the
    // shorts still complete, nothing wedges, and nothing is preempted.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    const LONG: usize = 24;
    const SHORT: usize = 6;
    // Big enough that two shorts coexist without pressure (so only the
    // long can be the casualty), small enough that the long plus a short
    // cannot both reach their peaks.
    let budget = (prefill_t + LONG - 1).max(2 * (prefill_t + SHORT));
    let mut cfg = config(2);
    cfg.step_policy = StepPolicy::ShortestFirst;
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some(budget);
    cfg.batch.preempt = false;
    let Some(server) = start(cfg) else { return };
    let rx_long = server.submit(vec![3, 1, 4, 1, 5, 9, 2, 6], LONG).unwrap();
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, SHORT).unwrap()
        })
        .collect();
    for rx in rx_shorts {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "short request starved: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), SHORT);
    }
    let long = rx_long.recv_timeout(Duration::from_secs(240)).unwrap();
    assert!(!long.ok(), "the long sequence cannot fit without preemption");
    assert!(
        long.error.as_deref().unwrap().contains("KV pages exhausted"),
        "{:?}",
        long.error
    );
    let m = server.shutdown();
    assert_eq!(m.preemptions, 0);
    assert_eq!(m.resumes, 0);
    assert_eq!(m.errors, 1);
}

/// Run one fixed workload through a configured fleet; returns the fleet
/// metrics and every request's tokens, in submission order.
fn run_fleet_workload(nodes: Vec<NodeConfig>) -> Option<(FleetMetrics, Vec<Vec<i32>>)> {
    let cfg = ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        route: RoutePolicy::RoundRobin,
        nodes,
        ..Default::default()
    };
    let server = start(cfg)?;
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, 6).unwrap()
        })
        .collect();
    let mut tokens = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        tokens.push(resp.tokens);
    }
    Some((server.shutdown_fleet(), tokens))
}

#[test]
fn heterogeneous_fleet_beats_either_card_alone() {
    // The fleet acceptance property: a 170HX + 90HX fleet under continuous
    // batching sustains strictly more simulated tokens/s than either card
    // alone on the same workload — throughput/Watt at fleet level is the
    // §6.2 deciding metric, and it needs both cards actually serving.
    let n170 = NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed);
    let n90 = NodeConfig::new(registry::cmp90hx(), FmadPolicy::Decomposed);
    let Some((both, _)) = run_fleet_workload(vec![n170.clone(), n90.clone()]) else {
        return;
    };
    let (only170, _) = run_fleet_workload(vec![n170]).unwrap();
    let (only90, _) = run_fleet_workload(vec![n90]).unwrap();

    // round-robin dispatch must have exercised both cards
    assert_eq!(both.nodes.len(), 2);
    for (name, m) in &both.nodes {
        assert!(m.tokens_out > 0, "node {name} served nothing");
        assert!(m.simulated_energy_j > 0.0, "node {name} accrued no energy");
    }
    let fleet_tps = both.sim_tokens_per_sec();
    assert!(
        fleet_tps > only170.sim_tokens_per_sec(),
        "fleet {fleet_tps} vs 170HX alone {}",
        only170.sim_tokens_per_sec()
    );
    assert!(
        fleet_tps > only90.sim_tokens_per_sec(),
        "fleet {fleet_tps} vs 90HX alone {}",
        only90.sim_tokens_per_sec()
    );
    // the fleet aggregate accounts every request exactly once
    assert_eq!(both.total().requests, 6);
    assert_eq!(both.total().tokens_out, 36);
}

/// Two identical 170HX nodes, round-robin routing, work stealing as given.
fn fleet2_config(steal: bool) -> ServerConfig {
    let mut cfg = config(4);
    cfg.route = RoutePolicy::RoundRobin;
    cfg.qos.steal = steal;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    cfg
}

#[test]
fn recovered_node_serves_again_after_mark_healthy() {
    // Regression for the router's missing recovery hook: a node excluded
    // from routing used to stay excluded for the server's lifetime.
    // Stealing is off so the only way node 1 can serve is via routing.
    let Some(server) = start(fleet2_config(false)) else { return };
    server.mark_unhealthy(1).unwrap();
    for i in 0..4 {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
        let resp = server
            .submit(prompt, 4)
            .unwrap()
            .recv_timeout(Duration::from_secs(240))
            .unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.node, 0, "unhealthy node must not serve");
    }
    let before = server.fleet_metrics();
    assert_eq!(before.nodes[1].1.requests, 0, "drained node must have idled");
    // The operator brings the node back: the dispatch stage must resume
    // routing to it with no restart.
    server.mark_healthy(1).unwrap();
    let mut nodes_seen = Vec::new();
    for i in 0..4 {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 5)) % 500 + 1).collect();
        let resp = server
            .submit(prompt, 4)
            .unwrap()
            .recv_timeout(Duration::from_secs(240))
            .unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        nodes_seen.push(resp.node);
    }
    assert!(
        nodes_seen.contains(&1),
        "recovered node must serve again, got {nodes_seen:?}"
    );
    let fm = server.shutdown_fleet();
    assert_eq!(fm.total().errors, 0);
    assert!(fm.nodes[1].1.requests > 0);
}

#[test]
fn idle_peer_steals_work_queued_behind_a_deep_node() {
    // Routing sends everything to node 0 (node 1 is marked out), so node
    // 0's queue runs deep while node 1 idles — the decide-once-routing
    // pathology. With stealing on, the idle worker must pull queued
    // requests across and serve them.
    let Some(server) = start(fleet2_config(true)) else { return };
    server.mark_unhealthy(1).unwrap();
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, 6).unwrap()
        })
        .collect();
    let mut nodes_seen = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
        nodes_seen.push(resp.node);
    }
    let fm = server.shutdown_fleet();
    assert_eq!(fm.total().errors, 0);
    assert_eq!(fm.total().requests, 8);
    assert!(
        nodes_seen.contains(&1),
        "an idle peer must steal and serve queued work, got {nodes_seen:?}"
    );
    assert!(
        fm.nodes[1].1.steals >= 1,
        "node 1 served only by stealing: {}",
        fm.nodes[1].1.steals
    );
    assert_eq!(
        fm.nodes[1].1.requests as usize,
        nodes_seen.iter().filter(|&&n| n == 1).count(),
        "stolen requests retire (and count) on the thief"
    );
}

#[test]
fn aging_gate_resumes_a_parked_sequence_under_sustained_shorts() {
    // The PR 3 waiting-queue starvation follow-up: under sustained short
    // traffic, a preempted long sequence used to park indefinitely —
    // every freed page went to a fresh short because resume-order alone
    // cannot reserve pages. With aging_rounds set, the worker freezes new
    // admissions once the parked sequence is overdue, resumes it within a
    // bounded number of rounds, and shields it from re-eviction.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    const LONG: usize = 24;
    const SHORT: usize = 6;
    const SHORTS_TOTAL: usize = 10;
    let budget = (prefill_t + LONG - 1).max(2 * (prefill_t + SHORT));
    let long_prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];

    // Reference: the same long request served without pressure.
    let Some(reference) = start(config(2)) else { return };
    let rx = reference.submit(long_prompt.clone(), LONG).unwrap();
    let expected_long = rx.recv_timeout(Duration::from_secs(240)).unwrap().tokens;
    drop(reference);

    let mut cfg = config(2);
    cfg.step_policy = StepPolicy::ShortestFirst;
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some(budget);
    cfg.batch.aging_rounds = 1;
    let Some(server) = start(cfg) else { return };
    let rx_long = server.submit(long_prompt, LONG).unwrap();
    // Sustained shorts: a closed loop keeps ~3 outstanding for the whole
    // run, so there is never a natural lull for the long one to slip in.
    let mut pending: VecDeque<_> = VecDeque::new();
    let mut submitted = 0usize;
    let mut served = 0usize;
    while served < SHORTS_TOTAL {
        while pending.len() < 3 && submitted < SHORTS_TOTAL {
            let prompt: Vec<i32> =
                (1..=8).map(|t| (t * (submitted as i32 + 2)) % 500 + 1).collect();
            pending.push_back(server.submit(prompt, SHORT).unwrap());
            submitted += 1;
        }
        let resp = pending
            .pop_front()
            .unwrap()
            .recv_timeout(Duration::from_secs(240))
            .unwrap();
        assert!(resp.ok(), "short request starved: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), SHORT);
        served += 1;
    }
    let long = rx_long.recv_timeout(Duration::from_secs(240)).unwrap();
    assert!(long.ok(), "{:?}", long.error);
    assert_eq!(
        long.tokens, expected_long,
        "aged resume must replay to the identical state"
    );
    assert!(long.preemptions >= 1, "pressure must have evicted the long one");
    assert!(
        long.preemptions <= 3,
        "the eviction shield must stop park/resume thrash, saw {}",
        long.preemptions
    );
    let m = server.shutdown();
    assert_eq!(m.errors, 0);
    assert!(
        m.aged_promotions >= 1,
        "the aging gate must have engaged for the parked sequence"
    );
}

/// Closed-loop flood: the light tenant keeps 2 long requests in flight
/// (8 total × 20 tokens); the heavy tenant keeps ~10× the light tenant's
/// outstanding token demand queued as short requests. Returns (light p99
/// seconds, Jain's index over per-tenant tokens served while the light
/// tenant was active).
fn flood_run(qos: bool) -> Option<(f64, f64)> {
    const LIGHT_N: usize = 8;
    const LIGHT_OUT: usize = 2;
    const LIGHT_TOK: usize = 20;
    const HEAVY_OUT: usize = 48;
    const HEAVY_TOK: usize = 8;
    let mut cfg = fleet2_config(qos);
    cfg.batch.max_batch = 1; // single-sequence nodes: comparable wall latency
    cfg.route = RoutePolicy::WeightedThroughput;
    cfg.qos.enabled = qos;
    cfg.qos.node_queue_depth = 1;
    cfg.qos.tenants = vec![TenantSpec::new("light", 1.0), TenantSpec::new("heavy", 1.0)];
    let server = Arc::new(Server::start(artifact_dir()?, cfg).unwrap());
    let light = server.tenant_id("light").unwrap();
    let heavy = server.tenant_id("heavy").unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let heavy_tokens = Arc::new(AtomicU64::new(0));
    let flood = {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let heavy_tokens = Arc::clone(&heavy_tokens);
        std::thread::spawn(move || {
            let mut next = 0i32;
            let mut pending = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                while pending.len() < HEAVY_OUT {
                    let prompt: Vec<i32> = (1..=8).map(|t| (t * (next + 11)) % 500 + 1).collect();
                    match server.submit_as(heavy, prompt, HEAVY_TOK) {
                        Ok(rx) => pending.push(rx),
                        Err(_) => break, // backpressure: retry after the poll
                    }
                    next += 1;
                }
                pending.retain(|rx| match rx.try_recv() {
                    Ok(resp) => {
                        if resp.ok() && !stop.load(Ordering::Relaxed) {
                            heavy_tokens.fetch_add(resp.tokens.len() as u64, Ordering::Relaxed);
                        }
                        false
                    }
                    Err(_) => true,
                });
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    let mut latencies = Vec::new();
    let mut light_tokens = 0u64;
    let mut inflight: VecDeque<_> = VecDeque::new();
    let mut submitted = 0usize;
    while latencies.len() < LIGHT_N {
        while inflight.len() < LIGHT_OUT && submitted < LIGHT_N {
            let prompt: Vec<i32> =
                (1..=8).map(|t| (t * (submitted as i32 + 2)) % 500 + 1).collect();
            match server.submit_as(light, prompt, LIGHT_TOK) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        let resp = inflight
            .pop_front()
            .unwrap()
            .recv_timeout(Duration::from_secs(600))
            .unwrap();
        assert!(resp.ok(), "light request failed: {:?}", resp.error);
        light_tokens += resp.tokens.len() as u64;
        latencies.push(resp.latency_s());
    }
    stop.store(true, Ordering::Relaxed);
    let heavy_window = heavy_tokens.load(Ordering::Relaxed);
    flood.join().unwrap();
    drop(server);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = latencies[((latencies.len() as f64 - 1.0) * 0.99).round() as usize];
    Some((p99, jain_index(&[light_tokens as f64, heavy_window as f64])))
}

#[test]
fn wfq_and_stealing_keep_a_flooded_light_tenant_within_its_sla() {
    // The acceptance scenario: one tenant floods a 2-card fleet at ~10×
    // another's demand. With the QoS layer on, the light tenant's p99
    // stays within 2× its solo-run p99 and the token split stays fair
    // (Jain ≥ 0.9); with it off (FIFO, no stealing), both are strictly
    // worse.
    let Some(dir) = artifact_dir() else { return };
    // Solo baseline: the light workload alone on the same fleet.
    let mut solo_cfg = fleet2_config(true);
    solo_cfg.batch.max_batch = 1;
    solo_cfg.route = RoutePolicy::WeightedThroughput;
    solo_cfg.qos.node_queue_depth = 1;
    let solo_server = Server::start(dir, solo_cfg).unwrap();
    let mut solo_lat = Vec::new();
    for i in 0..8 {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
        let resp = solo_server
            .submit(prompt, 20)
            .unwrap()
            .recv_timeout(Duration::from_secs(240))
            .unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        solo_lat.push(resp.latency_s());
    }
    drop(solo_server);
    solo_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let solo_p99 = solo_lat[((solo_lat.len() as f64 - 1.0) * 0.99).round() as usize];

    let (on_p99, on_jain) = flood_run(true).unwrap();
    let (off_p99, off_jain) = flood_run(false).unwrap();
    eprintln!(
        "fairness: solo p99 {:.0}ms | qos on p99 {:.0}ms jain {:.3} | qos off p99 {:.0}ms jain {:.3}",
        solo_p99 * 1e3,
        on_p99 * 1e3,
        on_jain,
        off_p99 * 1e3,
        off_jain,
    );
    assert!(
        on_p99 <= 2.0 * solo_p99,
        "QoS must hold the light tenant's p99 within 2× solo: {on_p99} vs solo {solo_p99}"
    );
    assert!(on_jain >= 0.9, "QoS must keep the token split fair: jain {on_jain}");
    assert!(
        off_p99 > on_p99,
        "disabling QoS must strictly worsen the light tenant's p99: {off_p99} vs {on_p99}"
    );
    assert!(
        off_jain < on_jain && off_jain < 0.9,
        "disabling QoS must strictly worsen fairness: {off_jain} vs {on_jain}"
    );
}

#[test]
fn single_node_fleet_matches_single_card_path_exactly() {
    // A fleet of one must be behaviourally identical to the legacy
    // single-card path: same per-request tokens, same counts.
    let Some((fleet, fleet_tokens)) =
        run_fleet_workload(vec![NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed)])
    else {
        return;
    };
    let (legacy, legacy_tokens) = run_fleet_workload(vec![]).unwrap();
    assert_eq!(fleet_tokens, legacy_tokens, "per-request results must match");
    assert_eq!(fleet.total().requests, legacy.total().requests);
    assert_eq!(fleet.total().tokens_out, legacy.total().tokens_out);
    assert_eq!(fleet.nodes.len(), 1);
    assert_eq!(legacy.nodes.len(), 1);
    assert_eq!(fleet.nodes[0].0, legacy.nodes[0].0, "same device identity");
}
