//! Kernel decomposition of llama.cpp's CUDA backend, per quant format.
//!
//! Prefill (pp512) folds a whole 512-token batch into one aggregate kernel:
//! - **float models** (f32/f16): GEMMs dispatch to prebuilt cuBLAS
//!   ([`KernelSource::Lib`]). On a card whose tensor pipe is dark, cuBLAS
//!   falls back to SIMT kernels: `cublasGemmEx` (the f32 path, after ggml's
//!   f16 conversion) lands on the *scalar*-half fallback; `cublasHgemm`
//!   (the f16 path) on the *packed*-half (`half2`) fallback. Neither is
//!   touched by `-fmad=false` — the paper's "f32/f16 show no gains".
//! - **quantized models**: JIT-compiled MMQ kernels — DP4A dot products
//!   (uncrippled) + per-block fp32 scale FMAs (crippled; restorable) +
//!   integer unpack ops.
//!
//! Decode (tg128) builds a per-token aggregate: MMVQ mat-vec kernels (a
//! `decode_float_frac` share of MACs in fp32 FFMA, rest DP4A), the f16
//! lm_head matvec, plus the per-step costs the simulator adds outside the
//! kernel: ~9 kernel launches per layer and the logits readback over the
//! card's PCIe link — on the CMP's x4 gen1 link this is a first-class
//! throughput term, on the A100's gen4 x16 it vanishes. That asymmetry is
//! why decode lands at 39–78% of the bandwidth-scaled theoretical (§4.3).

use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, MemPattern, Stmt, Traffic};

use super::model::ModelDesc;
use super::quant::QuantFormat;

/// llama.cpp MMQ/MMVQ kernels sustain ~50% of peak issue (shared-memory
/// bank conflicts, dependency stalls) — measured character of the real
/// kernels, and the efficiency the whole §4 calibration uses.
pub const MMQ_ISSUE_EFF: f64 = 0.5;
/// cuBLAS SIMT fallback GEMMs land around 35% of pipe peak at these small
/// matrix shapes (k = 1536).
pub const CUBLAS_FALLBACK_EFF: f64 = 0.31;
/// Kernel launches per transformer layer per step (qkv, rope, attn ×2,
/// o-proj, norm ×2, ffn ×3 fused → ≈9).
pub const KERNELS_PER_LAYER: f64 = 9.0;
/// Host-side launch latency per kernel, seconds.
pub const LAUNCH_S: f64 = 5e-6;

/// Conversion ops (f32→f16) per weight the ggml cuBLAS path performs when
/// feeding an f32 model through half-precision GEMM.
const CONVERT_OPS_PER_WEIGHT: f64 = 4.0;

/// Aggregate prefill kernel for `tokens` prompt tokens.
pub fn prefill_kernel(model: &ModelDesc, quant: &QuantFormat, tokens: u64) -> Kernel {
    let macs = model.macs_per_token(false) as f64 * tokens as f64;
    let attn_macs = model.attn_macs_per_token((tokens / 2) as u32) as f64 * tokens as f64;
    let mut body: Vec<Stmt> = Vec::new();

    match quant.name {
        "f32" => {
            // GemmEx scalar-half fallback + f32→f16 weight conversion once
            // per layer-GEMM per batch.
            body.push(Stmt::op(InstClass::Hfma, macs as u64));
            let convert = model.params_nonembed() as f64 * CONVERT_OPS_PER_WEIGHT;
            body.push(Stmt::op(InstClass::Fmul, convert as u64));
        }
        "f16" => {
            // cublasHgemm packed-half fallback.
            body.push(Stmt::op(InstClass::Hfma2, (macs / 2.0) as u64));
        }
        _ => {
            let blocks = macs / quant.block as f64;
            body.push(Stmt::op(InstClass::Dp4a, (macs / 4.0) as u64));
            body.push(Stmt::op(
                InstClass::Ffma,
                (blocks * quant.scale_fmas_per_block) as u64,
            ));
            body.push(Stmt::op(
                InstClass::Iadd,
                (blocks * quant.unpack_iops_per_block) as u64,
            ));
        }
    }
    // Attention scores stay f16 (KV cache is f16 in all six formats).
    body.push(Stmt::op(InstClass::Hfma2, (attn_macs / 2.0) as u64));
    // Softmax: one MUFU exp per score.
    body.push(Stmt::op(
        InstClass::Mufu,
        (model.q_heads as u64) * tokens * (tokens / 2),
    ));

    let weights = model.weight_bytes(quant);
    let activations = tokens * model.hidden as u64 * 4 * model.layers as u64 * 8;
    Kernel::new(format!("prefill.{}.{}", model.name, quant.name), 1, 256)
        .with_body(body)
        .with_traffic(Traffic {
            read_bytes: weights + activations,
            write_bytes: activations / 2,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: 0.3, // tile reuse in blocked GEMMs
        })
        .with_source(quant.source)
}

/// Aggregate decode kernel for ONE token at context position `pos`
/// (excludes launch + PCIe readback, added by the bench driver).
pub fn decode_kernel(model: &ModelDesc, quant: &QuantFormat, pos: u32) -> Kernel {
    let macs = model.macs_per_token(false) as f64;
    let lm_head_macs = model.params_embed() as f64;
    let attn_macs = model.attn_macs_per_token(pos) as f64;
    let mut body: Vec<Stmt> = Vec::new();

    match quant.name {
        "f32" => {
            // cublasSgemv: fp32 FFMA — crippled AND Lib (unfixable): the
            // f32 decode bar sits at the bottom of Graph 4-2.
            body.push(Stmt::op(InstClass::Ffma, macs as u64));
        }
        "f16" => {
            // half2 GEMV — uncrippled.
            body.push(Stmt::op(InstClass::Hfma2, (macs / 2.0) as u64));
        }
        _ => {
            let float_macs = macs * quant.decode_float_frac;
            let int_macs = macs - float_macs;
            let blocks = macs / quant.block as f64;
            body.push(Stmt::op(InstClass::Ffma, float_macs as u64));
            body.push(Stmt::op(InstClass::Dp4a, (int_macs / 4.0) as u64));
            body.push(Stmt::op(
                InstClass::Iadd,
                (blocks * quant.unpack_iops_per_block) as u64,
            ));
        }
    }
    // lm_head matvec on f16 embeddings (every decode step emits logits).
    body.push(Stmt::op(InstClass::Hfma2, (lm_head_macs / 2.0) as u64));
    // Attention over the KV cache.
    body.push(Stmt::op(InstClass::Hfma2, (attn_macs / 2.0) as u64));

    let weights = model.weight_bytes(quant);
    let kv = model.kv_bytes_per_pos() * pos as u64;
    Kernel::new(
        format!("decode.{}.{}@{}", model.name, quant.name, pos),
        1,
        256,
    )
    .with_traffic(Traffic {
        read_bytes: weights + kv,
        write_bytes: model.kv_bytes_per_pos() + model.hidden as u64 * 4 * 8,
        pattern: MemPattern::Coalesced,
        l2_hit_rate: 0.0, // streaming: every weight byte read exactly once
    })
    .with_body(body)
    .with_source(quant.source)
}

/// Per-step host overhead: kernel launches for all layers.
pub fn launch_overhead(model: &ModelDesc) -> f64 {
    model.layers as f64 * KERNELS_PER_LAYER * LAUNCH_S
}

/// Per-step logits readback + sampling round trip over a PCIe link.
pub fn readback_overhead(model: &ModelDesc, pcie: &crate::memhier::pcie::PcieLink) -> f64 {
    let logits_bytes = model.vocab as u64 * 4;
    pcie.transfer_time(logits_bytes) + 2.0 * 10e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ir::KernelSource;
    use crate::isa::mix::InstMix;
    use crate::isa::pass::{apply_fmad, FmadPolicy};
    use crate::llm::quant;

    fn qwen() -> ModelDesc {
        ModelDesc::qwen25_15b()
    }

    #[test]
    fn float_prefill_kernels_are_lib_sourced() {
        for q in [quant::F32, quant::F16] {
            let k = prefill_kernel(&qwen(), &q, 512);
            assert_eq!(k.source, KernelSource::Lib);
            // and therefore immune to the fmad pass
            assert_eq!(apply_fmad(&k, FmadPolicy::Decomposed).body, k.body);
        }
    }

    #[test]
    fn quantized_prefill_has_restorable_ffma() {
        let k = prefill_kernel(&qwen(), &quant::Q2_K, 512);
        let mix = InstMix::from_kernel(&k);
        assert!(mix.get(InstClass::Ffma) > 0);
        let after = InstMix::from_kernel(&apply_fmad(&k, FmadPolicy::Decomposed));
        assert_eq!(after.get(InstClass::Ffma), 0);
        assert!(after.get(InstClass::Fmul) > 0);
    }

    #[test]
    fn q2k_has_more_scale_math_than_q8() {
        let m2 = InstMix::from_kernel(&prefill_kernel(&qwen(), &quant::Q2_K, 512));
        let m8 = InstMix::from_kernel(&prefill_kernel(&qwen(), &quant::Q8_0, 512));
        assert!(m2.get(InstClass::Ffma) > 2 * m8.get(InstClass::Ffma));
    }

    #[test]
    fn decode_reads_whole_model_plus_kv() {
        let m = qwen();
        let k0 = decode_kernel(&m, &quant::Q8_0, 0);
        let k128 = decode_kernel(&m, &quant::Q8_0, 128);
        assert!(k0.traffic.read_bytes >= m.weight_bytes(&quant::Q8_0));
        assert_eq!(
            k128.traffic.read_bytes - k0.traffic.read_bytes,
            m.kv_bytes_per_pos() * 128
        );
    }

    #[test]
    fn readback_is_first_class_on_the_stock_link() {
        let m = qwen();
        let cmp = crate::memhier::pcie::PcieLink::cmp170hx_stock();
        let a100 = crate::memhier::pcie::PcieLink::new(crate::memhier::pcie::PcieGen::Gen4, 16);
        let slow = readback_overhead(&m, &cmp);
        let fast = readback_overhead(&m, &a100);
        assert!(slow > 5e-4, "{slow}"); // ~0.75 ms/token over x4 gen1
        assert!(slow / fast > 10.0, "{slow} vs {fast}");
    }

    #[test]
    fn launch_overhead_scales_with_layers() {
        let m = qwen();
        let t = launch_overhead(&m);
        assert!((t - 28.0 * 9.0 * 5e-6).abs() < 1e-12);
    }
}
