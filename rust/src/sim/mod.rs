//! Kernel-timing engine.
//!
//! Given a [`crate::isa::Kernel`] (post-fmad-pass) and a
//! [`crate::device::DeviceSpec`], the engine computes execution time, board
//! power and energy via an issue-rate/roofline hybrid:
//!
//! 1. lower the body to a whole-grid [`crate::isa::InstMix`];
//! 2. per execution pipe, sum `count / (SMs × rate × throttle × clock)` —
//!    classes on one pipe serialize, distinct pipes overlap;
//! 3. memory time from [`crate::memhier`] (pattern-derated bandwidth, L2
//!    split);
//! 4. kernel time = max(pipe times, memory time, wave-quantized launch
//!    floor), then DVFS-derate if the power model says the activity exceeds
//!    TDP.
//!
//! The engine also returns an achieved-rate report (TFLOPS/TIOPs/GB/s) in
//! the units the paper's graphs use.

pub mod engine;
pub mod occupancy;

pub use engine::{simulate, KernelTiming, SimConfig};
