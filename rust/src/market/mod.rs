//! Market & reuse-economics models (§1.1.1, Appendix Ex.1, §6.2).
//!
//! - [`sales`] — the paper's CMP sales-volume estimation: split NVIDIA's
//!   $550M FY2022 CMP revenue across the five models under three mix
//!   scenarios and divide by estimated ASPs (Tables 1-1/1-2).
//! - [`tco`] — reuse value: $/TFLOPS and $/(token/s) for refurbished CMP
//!   cards against the A100 reference, plus fleet sizing for an edge
//!   deployment (the §6.2 recommendation).

pub mod sales;
pub mod tco;

pub use sales::{estimate_sales, SalesEstimate, Scenario};
pub use tco::{fleet_for_throughput, FleetPlan, ReuseValue};
