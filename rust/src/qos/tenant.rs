//! Tenant identity and registry.
//!
//! The fleet's clients are **tenants**: named identities with a fair-share
//! weight and optional hard caps (a sustained token rate, a lifetime
//! simulated-energy budget priced via the per-card overlay). Every
//! [`crate::coordinator::GenRequest`] carries a [`TenantId`]; the QoS
//! dispatch stage resolves it against the [`TenantRegistry`] built at
//! server start. Tenant 0 is always the **default** tenant (weight 1, no
//! caps) so the single-client path needs no registration at all.

use anyhow::{bail, Result};

/// Index into the [`TenantRegistry`]. Stable for the server's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub usize);

/// One tenant's contract with the fleet.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// Fair-share weight for deficit-round-robin queueing (relative; a
    /// weight-2 tenant gets twice the contended service of a weight-1
    /// tenant). Must be finite and positive.
    pub weight: f64,
    /// Optional sustained admission rate, generated tokens per second.
    /// Enforced at the dispatch stage with a leaky bucket; over-rate
    /// tenants are *deferred* (their lane waits), not errored.
    pub tok_s: Option<f64>,
    /// Optional lifetime simulated-energy budget, joules, priced with the
    /// routed node's calibrated overlay. Exhausted budgets are terminal:
    /// further requests are shed with an error.
    pub energy_budget_j: Option<f64>,
    /// Optional per-tenant SLO: end-to-end latency target, milliseconds.
    /// Stamped onto every one of the tenant's requests as its deadline
    /// (overriding the server-wide `--deadline-ms`), scored in the
    /// per-tenant attainment rollup, and — when admission control is on —
    /// enforced *at submit*: a request whose predicted completion
    /// violates it is shed before any prefill is wasted.
    pub slo_ms: Option<f64>,
}

impl TenantSpec {
    /// An uncapped tenant with the given fair-share weight.
    pub fn new(name: impl Into<String>, weight: f64) -> Self {
        TenantSpec {
            name: name.into(),
            weight,
            tok_s: None,
            energy_budget_j: None,
            slo_ms: None,
        }
    }

    /// The SLO contract as a wall-clock duration, when declared.
    pub fn slo(&self) -> Option<std::time::Duration> {
        self.slo_ms.map(|ms| std::time::Duration::from_secs_f64(ms / 1000.0))
    }

    /// The SLO contract in seconds, when declared.
    pub fn slo_s(&self) -> Option<f64> {
        self.slo_ms.map(|ms| ms / 1000.0)
    }

    /// Parse the CLI form `name:weight[:tok_s][:joules][:slo_ms]`. Empty
    /// optional segments skip a cap: `burst:2::500` is weight 2, no rate
    /// cap, a 500 J energy budget; `edge:1:::250` contracts only a
    /// 250 ms SLO.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() < 2 || parts.len() > 5 {
            bail!("tenant spec {s:?} is not name:weight[:tok_s][:joules][:slo_ms]");
        }
        let name = parts[0].trim();
        if name.is_empty() {
            bail!("tenant spec {s:?} has an empty name");
        }
        let weight: f64 = parts[1]
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("tenant {name}: bad weight {:?}", parts[1]))?;
        let optional = |i: usize, what: &str| -> Result<Option<f64>> {
            match parts.get(i).map(|p| p.trim()) {
                None | Some("") => Ok(None),
                Some(v) => v
                    .parse()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("tenant {name}: bad {what} {v:?}")),
            }
        };
        let spec = TenantSpec {
            name: name.to_string(),
            weight,
            tok_s: optional(2, "tok_s")?,
            energy_budget_j: optional(3, "joules")?,
            slo_ms: optional(4, "slo_ms")?,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        if !(self.weight.is_finite() && self.weight > 0.0) {
            bail!("tenant {}: weight must be finite and positive", self.name);
        }
        for (cap, what) in [
            (self.tok_s, "tok_s"),
            (self.energy_budget_j, "energy budget"),
            (self.slo_ms, "slo_ms"),
        ] {
            if let Some(v) = cap {
                if !(v.is_finite() && v > 0.0) {
                    bail!("tenant {}: {what} must be finite and positive", self.name);
                }
            }
        }
        Ok(())
    }
}

/// The server's tenant table, fixed at start. Index 0 is always the
/// default tenant; an explicit spec named `default` replaces its weight
/// and caps rather than adding a second identity.
#[derive(Clone, Debug)]
pub struct TenantRegistry {
    specs: Vec<TenantSpec>,
}

impl TenantRegistry {
    /// The implicit tenant every un-attributed request belongs to.
    pub const DEFAULT: TenantId = TenantId(0);

    pub fn new(extra: Vec<TenantSpec>) -> Result<Self> {
        let mut specs = vec![TenantSpec::new("default", 1.0)];
        for spec in extra {
            spec.validate()?;
            if spec.name == "default" {
                specs[0] = spec;
            } else if specs.iter().any(|s| s.name == spec.name) {
                bail!("duplicate tenant {:?}", spec.name);
            } else {
                specs.push(spec);
            }
        }
        Ok(TenantRegistry { specs })
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        false // the default tenant always exists
    }

    pub fn id(&self, name: &str) -> Option<TenantId> {
        self.specs.iter().position(|s| s.name == name).map(TenantId)
    }

    /// Spec lookup; panics on a foreign id (ids only come from this
    /// registry).
    pub fn spec(&self, t: TenantId) -> &TenantSpec {
        &self.specs[t.0]
    }

    pub fn contains(&self, t: TenantId) -> bool {
        t.0 < self.specs.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (TenantId, &TenantSpec)> {
        self.specs.iter().enumerate().map(|(i, s)| (TenantId(i), s))
    }

    /// Per-tenant DRR weights, in id order.
    pub fn weights(&self) -> Vec<f64> {
        self.specs.iter().map(|s| s.weight).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_always_has_a_default_tenant() {
        let r = TenantRegistry::new(vec![]).unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r.id("default"), Some(TenantRegistry::DEFAULT));
        let d = r.spec(TenantRegistry::DEFAULT);
        assert_eq!(d.weight, 1.0);
        assert!(d.tok_s.is_none() && d.energy_budget_j.is_none());
    }

    #[test]
    fn extra_tenants_register_after_the_default() {
        let r = TenantRegistry::new(vec![
            TenantSpec::new("light", 1.0),
            TenantSpec::new("heavy", 3.0),
        ])
        .unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.id("light"), Some(TenantId(1)));
        assert_eq!(r.id("heavy"), Some(TenantId(2)));
        assert_eq!(r.id("nobody"), None);
        assert_eq!(r.weights(), vec![1.0, 1.0, 3.0]);
    }

    #[test]
    fn explicit_default_spec_replaces_tenant_zero() {
        let mut d = TenantSpec::new("default", 2.5);
        d.tok_s = Some(100.0);
        let r = TenantRegistry::new(vec![d, TenantSpec::new("other", 1.0)]).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.spec(TenantRegistry::DEFAULT).weight, 2.5);
        assert_eq!(r.spec(TenantRegistry::DEFAULT).tok_s, Some(100.0));
    }

    #[test]
    fn duplicate_names_are_rejected() {
        let err = TenantRegistry::new(vec![
            TenantSpec::new("a", 1.0),
            TenantSpec::new("a", 2.0),
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn parse_accepts_every_cap_combination() {
        let t = TenantSpec::parse("light:2").unwrap();
        assert_eq!((t.name.as_str(), t.weight), ("light", 2.0));
        assert!(t.tok_s.is_none() && t.energy_budget_j.is_none());

        let t = TenantSpec::parse("metered:1:50").unwrap();
        assert_eq!(t.tok_s, Some(50.0));
        assert!(t.energy_budget_j.is_none());

        let t = TenantSpec::parse("capped:1:50:1000").unwrap();
        assert_eq!(t.tok_s, Some(50.0));
        assert_eq!(t.energy_budget_j, Some(1000.0));

        let t = TenantSpec::parse("burst:2::500").unwrap();
        assert!(t.tok_s.is_none());
        assert_eq!(t.energy_budget_j, Some(500.0));
        assert!(t.slo_ms.is_none() && t.slo().is_none());

        let t = TenantSpec::parse("edge:1:::250").unwrap();
        assert!(t.tok_s.is_none() && t.energy_budget_j.is_none());
        assert_eq!(t.slo_ms, Some(250.0));
        assert_eq!(t.slo(), Some(std::time::Duration::from_millis(250)));
        assert_eq!(t.slo_s(), Some(0.25));

        let t = TenantSpec::parse("full:2:50:1000:500").unwrap();
        assert_eq!(
            (t.tok_s, t.energy_budget_j, t.slo_ms),
            (Some(50.0), Some(1000.0), Some(500.0))
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "noweight",
            ":1",
            "x:zero",
            "x:1:fast",
            "x:1:10:1:extra",
            "x:1:10:1:5:more",
            "x:-1",
            "x:0",
            "x:1:-5",
            "x:1:10:-2",
            "x:1:::-250",
            "x:1:::0",
        ] {
            assert!(TenantSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
