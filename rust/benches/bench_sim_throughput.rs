//! Simulation-substrate throughput: kernels-simulated/sec on a
//! calibration-sized sweep (the llama-bench 6-quant × 2-policy grid, both
//! prefill and decode kernels, across a small heterogeneous fleet).
//!
//! Two pipelines are timed:
//! - **baseline (seed shape)** — every cell rebuilds its kernel IR, applies
//!   the fmad pass, and calls `simulate()` (which re-lowers the IR per
//!   call), sequentially — the per-launch allocation storm this PR removes;
//! - **lowered + batched** — the grid is lowered once per iteration
//!   ([`LoweredKernel`]) and all cells fan out through `sim::batch` worker
//!   threads.
//!
//! The ratio is the PR's headline number (target: ≥ 5×). Results are
//! printed and also written to `BENCH_sim_throughput.json` at the repo root
//! so the perf trajectory is recorded across PRs — each bench **upserts
//! only the rows it owns** (`sim_throughput`, `serve_concurrency`,
//! `serve_prefix_cache` here; `serve_fairness` belongs to
//! bench_e2e_serve), so no bench's numbers silently depend on another
//! bench rerunning.

use cmphx::bench_harness::{time_fn, upsert_bench_row};
use cmphx::coordinator::KvPager;
use cmphx::device::registry;
use cmphx::isa::pass::{apply_fmad, FmadPolicy};
use cmphx::llm::kernels::{decode_kernel, prefill_kernel};
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::model::ModelDesc;
use cmphx::llm::quant;
use cmphx::sim::batch::{self, SweepJob};
use cmphx::sim::simulate;

/// Serving-concurrency row: how many concurrent sequences a 170HX admits
/// under the paged allocator vs the replaced fixed-slot allocator, at a
/// long-context operating point (context 4× the mean sequence length —
/// the regime where worst-case reservation wastes most of the card).
/// Deterministic arithmetic, no PJRT needed.
struct ServeConcurrency {
    context: usize,
    mean_seq: usize,
    block_positions: usize,
    fixed_slot_seqs: usize,
    paged_seqs: usize,
}

fn pager_170hx(block_positions: usize) -> KvPager {
    let model = ModelDesc::qwen25_15b();
    let dev = registry::cmp170hx();
    KvPager::new(
        block_positions,
        model.kv_bytes_per_pos(),
        dev.mem.capacity_bytes,
        model.weight_bytes(&quant::Q8_0),
    )
    .expect("Qwen2.5-1.5B q8_0 fits the 170HX")
}

fn serve_concurrency() -> ServeConcurrency {
    let block_positions = 16;
    let context = 4096;
    let mean_seq = 1024; // prompt + mean generation = context / 4
    let pager = pager_170hx(block_positions);
    ServeConcurrency {
        context,
        mean_seq,
        block_positions,
        fixed_slot_seqs: pager.fixed_slot_capacity(context),
        paged_seqs: pager.admissible(mean_seq),
    }
}

/// Prefix-cache row: at the same operating point, every sequence shares a
/// 512-position system prompt — admission through the chain-hash index
/// pins the shared blocks once and allocates only each sequence's
/// private tail. Deterministic allocator arithmetic, no PJRT needed.
struct ServePrefixCache {
    shared_positions: usize,
    paged_seqs: usize,
    prefix_cached_seqs: usize,
}

fn serve_prefix_cache() -> ServePrefixCache {
    let block_positions = 16;
    let mean_seq = 1024;
    let shared = 512;
    let mut pager = pager_170hx(block_positions);
    let paged_seqs = pager.admissible(mean_seq);
    let mut admitted = 0usize;
    loop {
        // mean-seq windows: `shared` common positions + a unique tail
        let window: Vec<i32> = (0..mean_seq)
            .map(|i| if i < shared { i as i32 + 1 } else { admitted as i32 * 10_000 + i as i32 })
            .collect();
        if pager.admit_prompt(&window).is_none() {
            break;
        }
        admitted += 1;
    }
    ServePrefixCache { shared_positions: shared, paged_seqs, prefix_cached_seqs: admitted }
}

fn main() {
    let bench = LlamaBench::default();
    let devices = [
        registry::cmp170hx(),
        registry::cmp170hx_x16(),
        registry::a100_pcie(),
    ];
    let policies = [FmadPolicy::Fused, FmadPolicy::Decomposed];
    // Cells per sweep: 6 quants × 2 policies × 2 kernels × |devices|.
    let cells = (quant::ALL.len() * policies.len() * 2 * devices.len()) as f64;

    // --- baseline: rebuild + re-lower per simulate() call, sequential.
    // Same per-cell configs as the lowered arm so both arms simulate the
    // identical workload; only the pipeline differs. ---
    let pos = bench.gen_tokens / 2;
    let baseline = time_fn(2, 10, || {
        for q in quant::ALL {
            let prefill_cfg = LlamaBench::prefill_config(q);
            let decode_cfg = LlamaBench::decode_config();
            for policy in policies {
                for dev in &devices {
                    let pk = apply_fmad(
                        &prefill_kernel(&bench.model, q, bench.prompt_tokens),
                        policy,
                    );
                    let dk = apply_fmad(&decode_kernel(&bench.model, q, pos), policy);
                    std::hint::black_box(simulate(&pk, dev, &prefill_cfg));
                    std::hint::black_box(simulate(&dk, dev, &decode_cfg));
                }
            }
        }
    });

    // --- lowered + batched: one IR walk per kernel, threaded fan-out ---
    let lowered = time_fn(2, 10, || {
        let grid = bench.lower_grid();
        let mut jobs = Vec::with_capacity(grid.len() * 2);
        for cell in &grid {
            jobs.push(SweepJob { kernel: &cell.prefill, cfg: cell.prefill_cfg });
            jobs.push(SweepJob { kernel: &cell.decode, cfg: cell.decode_cfg });
        }
        std::hint::black_box(batch::run_jobs(&jobs, &devices));
    });

    let baseline_kps = baseline.per_sec(cells);
    let lowered_kps = lowered.per_sec(cells);
    let speedup = lowered_kps / baseline_kps;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    println!("== sim throughput: llama-bench grid × {} devices ==", devices.len());
    println!("cells per sweep:        {cells:.0}");
    println!(
        "baseline (re-lower):    {baseline_kps:>12.0} kernels/s  (mean {:.3} ms)",
        baseline.mean_s * 1e3
    );
    println!(
        "lowered + batched:      {lowered_kps:>12.0} kernels/s  (mean {:.3} ms)",
        lowered.mean_s * 1e3
    );
    println!("speedup:                {speedup:>12.2}×  ({threads} hw threads)");

    let sc = serve_concurrency();
    let concurrency_ratio = sc.paged_seqs as f64 / sc.fixed_slot_seqs.max(1) as f64;
    println!(
        "serve concurrency (170HX, Qwen2.5-1.5B q8_0, ctx {} / mean seq {}): \
         fixed-slot {} seqs vs paged {} seqs ({concurrency_ratio:.2}×)",
        sc.context, sc.mean_seq, sc.fixed_slot_seqs, sc.paged_seqs,
    );
    let pc = serve_prefix_cache();
    let prefix_ratio = pc.prefix_cached_seqs as f64 / pc.paged_seqs.max(1) as f64;
    println!(
        "serve prefix cache (shared {}-position system prompt): paged {} seqs vs \
         prefix-cached {} seqs ({prefix_ratio:.2}×)",
        pc.shared_positions, pc.paged_seqs, pc.prefix_cached_seqs,
    );

    // Row-owned read-modify-write: this bench updates only its rows;
    // bench_e2e_serve's serve_fairness row (and anything else) survives.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(
        &out,
        "sim_throughput",
        &format!(
            "{{\n    \"sweep\": \"llamabench 6-quant x 2-policy x prefill+decode x {} devices\",\n    \
             \"cells_per_sweep\": {},\n    \
             \"baseline_relower_kernels_per_sec\": {baseline_kps:.1},\n    \
             \"lowered_batched_kernels_per_sec\": {lowered_kps:.1},\n    \
             \"speedup\": {speedup:.2},\n    \"hw_threads\": {threads}\n  }}",
            devices.len(),
            cells as u64,
        ),
    );
    upsert_bench_row(
        &out,
        "serve_concurrency",
        &format!(
            "{{\n    \"device\": \"CMP 170HX\",\n    \"model\": \"Qwen2.5-1.5B\",\n    \
             \"quant\": \"q8_0\",\n    \"context\": {},\n    \"mean_seq_positions\": {},\n    \
             \"kv_block_positions\": {},\n    \"fixed_slot_seqs\": {},\n    \
             \"paged_seqs\": {},\n    \"ratio\": {concurrency_ratio:.2}\n  }}",
            sc.context, sc.mean_seq, sc.block_positions, sc.fixed_slot_seqs, sc.paged_seqs,
        ),
    );
    upsert_bench_row(
        &out,
        "serve_prefix_cache",
        &format!(
            "{{\n    \"device\": \"CMP 170HX\",\n    \"model\": \"Qwen2.5-1.5B\",\n    \
             \"quant\": \"q8_0\",\n    \"context\": {},\n    \"mean_seq_positions\": {},\n    \
             \"shared_prefix_positions\": {},\n    \"kv_block_positions\": {},\n    \
             \"paged_seqs\": {},\n    \"prefix_cached_seqs\": {},\n    \
             \"ratio\": {prefix_ratio:.2}\n  }}",
            sc.context,
            sc.mean_seq,
            pc.shared_positions,
            sc.block_positions,
            pc.paged_seqs,
            pc.prefix_cached_seqs,
        ),
    );
}
