//! Sales-volume estimation (Table 1-2, methodology in Appendix Ex.1).

use crate::calibration as cal;

/// A revenue-mix scenario: percentage of CMP revenue attributed to each of
/// the five models (Table 1-1 row order: 30HX, 40HX, 50HX, 90HX, 170HX).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scenario {
    pub name: &'static str,
    pub shares_pct: [f64; 5],
}

impl Scenario {
    pub fn a() -> Self {
        Scenario { name: "A", shares_pct: cal::SCENARIO_A }
    }
    pub fn b() -> Self {
        Scenario { name: "B", shares_pct: cal::SCENARIO_B }
    }
    pub fn c() -> Self {
        Scenario { name: "C", shares_pct: cal::SCENARIO_C }
    }
    pub fn all() -> [Scenario; 3] {
        [Self::a(), Self::b(), Self::c()]
    }
}

/// Per-model sales estimate under one scenario.
#[derive(Clone, Debug)]
pub struct SalesEstimate {
    pub scenario: &'static str,
    /// `(model, asp_usd, estimated_units)` per Table 1-1 row.
    pub rows: Vec<(&'static str, f64, f64)>,
    pub total_units: f64,
}

/// Estimate unit sales: `units_i = revenue × share_i / asp_i` (Ex.1).
pub fn estimate_sales(revenue_usd: f64, scenario: &Scenario) -> SalesEstimate {
    assert!(
        (scenario.shares_pct.iter().sum::<f64>() - 100.0).abs() < 1e-6,
        "shares must sum to 100%"
    );
    let mut rows = Vec::with_capacity(5);
    let mut total = 0.0;
    for (i, &(model, asp, _)) in cal::TABLE_1_1.iter().enumerate() {
        let units = revenue_usd * scenario.shares_pct[i] / 100.0 / asp;
        rows.push((model, asp, units));
        total += units;
    }
    SalesEstimate {
        scenario: scenario.name,
        rows,
        total_units: total,
    }
}

/// The paper's headline: hundreds of thousands of stranded cards.
pub fn stranded_cards_min() -> f64 {
    Scenario::all()
        .iter()
        .map(|s| estimate_sales(cal::CMP_REVENUE_USD, s).total_units)
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, forall, Rng};

    #[test]
    fn scenario_totals_match_table_1_2() {
        for (scenario, (expected, rtol)) in
            Scenario::all().iter().zip(cal::TABLE_1_2_TOTALS.iter())
        {
            let est = estimate_sales(cal::CMP_REVENUE_USD, scenario);
            assert_close(est.total_units, *expected, *rtol);
        }
    }

    #[test]
    fn scenario_a_170hx_units_match_paper() {
        // Table 1-2: CMP 170HX under scenario A ≈ 18,333 units.
        let est = estimate_sales(cal::CMP_REVENUE_USD, &Scenario::a());
        let (_, _, units) = est.rows[4];
        assert_close(units, 18_333.0, 0.01);
    }

    #[test]
    fn scenario_b_40hx_units_match_paper() {
        // Table 1-2: CMP 40HX under scenario B ≈ 253,846 units.
        let est = estimate_sales(cal::CMP_REVENUE_USD, &Scenario::b());
        let (_, _, units) = est.rows[1];
        assert_close(units, 253_846.0, 0.01);
    }

    #[test]
    fn hundreds_of_thousands_stranded() {
        // §1.1.1's conclusion.
        assert!(stranded_cards_min() > 400_000.0);
    }

    #[test]
    fn prop_sales_scale_linearly_with_revenue() {
        forall(0x5A1E5, 100, |rng: &mut Rng| {
            let rev = rng.f64_range(1e6, 1e10);
            let s = Scenario::a();
            let e1 = estimate_sales(rev, &s);
            let e2 = estimate_sales(2.0 * rev, &s);
            assert_close(e2.total_units, 2.0 * e1.total_units, 1e-9);
        });
    }

    #[test]
    fn prop_units_conserve_revenue() {
        // Σ units_i × asp_i == revenue, for any valid mix.
        forall(0xC0, 100, |rng: &mut Rng| {
            let mut shares = [0.0f64; 5];
            let mut rem = 100.0;
            for i in 0..4 {
                shares[i] = rng.f64_range(0.0, rem);
                rem -= shares[i];
            }
            shares[4] = rem;
            let s = Scenario { name: "rand", shares_pct: shares };
            let est = estimate_sales(cal::CMP_REVENUE_USD, &s);
            let back: f64 = est.rows.iter().map(|(_, asp, u)| asp * u).sum();
            assert_close(back, cal::CMP_REVENUE_USD, 1e-9);
        });
    }

    #[test]
    #[should_panic]
    fn rejects_invalid_mix() {
        let s = Scenario { name: "bad", shares_pct: [50.0; 5] };
        estimate_sales(1e6, &s);
    }
}
