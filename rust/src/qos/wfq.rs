//! Deficit-round-robin weighted fair queueing across tenants, with an
//! aging promoter.
//!
//! The admission queue the dispatch stage drains. Each tenant owns a FIFO
//! **lane**; a rotating cursor funds the lane it visits with one quantum
//! × weight of deficit and serves that lane's head entries while the
//! deficit covers their cost (cost = the request's `max_tokens`, i.e.
//! service is measured in generated tokens, the unit the overlay prices).
//! A flooding tenant therefore fills only its own lane — its backlog
//! cannot delay another lane by more than roughly one quantum.
//!
//! The **aging promoter** bounds worst-case wait regardless of weights: an
//! entry that has waited through `aging_pops` serves since arrival *while
//! its lane went unserved that whole stretch* is served next (its lane's
//! deficit goes negative and the debt persists until repaid), so a
//! low-weight tenant's request cannot be parked indefinitely behind
//! high-weight lanes. Both conditions matter: age alone would let a deep
//! flood — whose lane head is always old but whose lane is served
//! constantly — trip the promoter on every pop and collapse WFQ into
//! global FIFO, exactly the failure mode this queue exists to prevent.
//! `aging_pops = 0` degenerates to global FIFO by arrival order.
//!
//! [`AdmissionQueue`] wraps the DRR queue together with the plain FIFO it
//! replaces, so the fairness ablation (WFQ on/off) is a constructor flag
//! rather than two dispatch paths.

use std::collections::VecDeque;

use super::tenant::TenantId;

/// Default DRR quantum, in cost units (generated tokens) per round per
/// unit weight. One quantum ≈ two typical short requests: small enough
/// that lanes interleave tightly, large enough that a lane drains a
/// request per visit.
pub const DEFAULT_QUANTUM: f64 = 16.0;

/// Outcome of one pop attempt.
#[derive(Debug, PartialEq)]
pub enum Popped<T> {
    Item(TenantId, T),
    /// Work is queued but every head was refused by the eligibility
    /// predicate (rate-capped tenants). Carries the smallest refused head
    /// cost, so the caller can sleep until a bucket could actually cover
    /// it instead of polling.
    Blocked(f64),
    Empty,
}

#[derive(Debug)]
struct Entry<T> {
    cost: f64,
    /// Pop counter at arrival — the aging clock (overdue after
    /// `aging_pops` pops).
    born: u64,
    /// Global arrival sequence — total order across lanes, so the aging
    /// promoter serves the genuinely oldest overdue entry first.
    arrival: u64,
    item: T,
}

#[derive(Debug)]
struct Lane<T> {
    weight: f64,
    deficit: f64,
    /// Whether the cursor already funded this lane on its current visit
    /// (quantum is per visit, not per pop).
    funded: bool,
    /// Serve-count when this lane last served — the starvation clock the
    /// aging promoter checks.
    last_served: u64,
    q: VecDeque<Entry<T>>,
}

/// Arrival-sequence space reserved for front-of-queue re-entries
/// (node-death rescues): normal pushes number upward from here and front
/// pushes number downward below it, so a rescued entry always reads as
/// *older* than every normally-arrived one to the aging promoter.
const FRONT_ARRIVALS: u64 = 1 << 32;

/// The DRR weighted fair queue.
#[derive(Debug)]
pub struct WfqQueue<T> {
    lanes: Vec<Lane<T>>,
    cursor: usize,
    pops: u64,
    arrivals: u64,
    /// Next front-push arrival sequence (counts down from
    /// [`FRONT_ARRIVALS`]).
    front_arrivals: u64,
    aging_pops: u64,
    quantum: f64,
    len: usize,
}

impl<T> WfqQueue<T> {
    pub fn new(weights: &[f64], aging_pops: u64) -> Self {
        Self::with_quantum(weights, aging_pops, DEFAULT_QUANTUM)
    }

    pub fn with_quantum(weights: &[f64], aging_pops: u64, quantum: f64) -> Self {
        assert!(!weights.is_empty(), "WFQ needs at least one lane");
        assert!(quantum > 0.0, "quantum must be positive");
        // A zero/negative weight would fund its lane nothing per wrap and
        // spin the pop loop forever; the registry validates this too.
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "lane weights must be finite and positive"
        );
        WfqQueue {
            lanes: weights
                .iter()
                .map(|&weight| Lane {
                    weight,
                    deficit: 0.0,
                    funded: false,
                    last_served: 0,
                    q: VecDeque::new(),
                })
                .collect(),
            cursor: 0,
            pops: 0,
            arrivals: 0,
            front_arrivals: FRONT_ARRIVALS,
            aging_pops,
            quantum,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, t: TenantId, cost: f64, item: T) {
        let arrival = FRONT_ARRIVALS + self.arrivals;
        self.arrivals += 1;
        self.lanes[t.0].q.push_back(Entry { cost, born: self.pops, arrival, item });
        self.len += 1;
    }

    /// Re-enter an item at the **front** of its tenant's lane — the
    /// node-death rescue path. The entry is stamped as old as the aging
    /// clock allows (born `aging_pops` serves in the past, arrival below
    /// every normal push), so it is first in line within its lane
    /// immediately and first for the aging promoter as soon as the lane
    /// counts as starved. The request already waited once and already
    /// burned card-seconds; making it re-queue behind the backlog would
    /// double-charge the fault to one tenant.
    pub fn push_front(&mut self, t: TenantId, cost: f64, item: T) {
        self.front_arrivals = self.front_arrivals.saturating_sub(1);
        let born = self.pops.saturating_sub(self.aging_pops);
        self.lanes[t.0].q.push_front(Entry {
            cost,
            born,
            arrival: self.front_arrivals,
            item,
        });
        self.len += 1;
    }

    pub fn pop(&mut self) -> Popped<T> {
        self.pop_eligible(|_, _| true)
    }

    /// Pop the next entry per DRR order, consulting `eligible(tenant,
    /// head_cost)` before serving any lane — rate-capped lanes are skipped
    /// (deferred, not reordered within their lane). Returns
    /// [`Popped::Blocked`] when work is queued but nothing is eligible.
    /// Only actual serves advance the aging clock, so blocked polls
    /// cannot ripen anything.
    pub fn pop_eligible(&mut self, mut eligible: impl FnMut(TenantId, f64) -> bool) -> Popped<T> {
        if self.len == 0 {
            return Popped::Empty;
        }
        let pop_seq = self.pops;

        // Aging promoter: the oldest entry that is both overdue (waited ≥
        // aging_pops serves since arrival) *and* starved (its lane went
        // unserved that whole stretch) is served out of DRR order; its
        // lane pays the cost as deficit debt. The starvation condition
        // keeps a flood — old heads, constantly-served lane — from
        // tripping the promoter and turning WFQ into FIFO.
        let overdue = self
            .lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| {
                l.q.front().map(|e| (e.arrival, e.born, l.last_served, i, e.cost))
            })
            .filter(|&(_, born, last_served, _, _)| {
                pop_seq.saturating_sub(born) >= self.aging_pops
                    && pop_seq.saturating_sub(last_served) >= self.aging_pops
            })
            .min_by_key(|&(arrival, ..)| arrival);
        if let Some((_, _, _, i, cost)) = overdue {
            if eligible(TenantId(i), cost) {
                return self.take(i);
            }
        }

        let n = self.lanes.len();
        let mut since_wrap = 0usize;
        let mut wrap_had_eligible = false;
        let mut min_refused = f64::INFINITY;
        loop {
            let i = self.cursor;
            let head_cost = self.lanes[i].q.front().map(|e| e.cost);
            let mut serve = false;
            if let Some(cost) = head_cost {
                if eligible(TenantId(i), cost) {
                    wrap_had_eligible = true;
                    if !self.lanes[i].funded {
                        let quantum = self.quantum * self.lanes[i].weight;
                        self.lanes[i].deficit += quantum;
                        self.lanes[i].funded = true;
                    }
                    serve = self.lanes[i].deficit + 1e-9 >= cost;
                } else {
                    min_refused = min_refused.min(cost);
                }
            }
            if serve {
                return self.take(i);
            }
            // Leaving the lane: it refunds when the cursor comes back, and
            // an emptied lane forfeits leftover *credit* — debt (a negative
            // deficit from an aging promotion) persists until repaid, so a
            // drip-feeding tenant cannot shed what it owes by letting its
            // lane run dry.
            self.lanes[i].funded = false;
            if head_cost.is_none() {
                self.lanes[i].deficit = self.lanes[i].deficit.min(0.0);
            }
            self.cursor = (i + 1) % n;
            since_wrap += 1;
            if since_wrap == n {
                if !wrap_had_eligible {
                    return Popped::Blocked(if min_refused.is_finite() {
                        min_refused
                    } else {
                        1.0
                    });
                }
                // Eligible but underfunded lanes accumulate one quantum per
                // wrap; keep rotating until one can afford its head.
                since_wrap = 0;
                wrap_had_eligible = false;
            }
        }
    }

    #[cfg(test)]
    fn lane_deficit(&self, i: usize) -> f64 {
        self.lanes[i].deficit
    }

    /// Per-lane DRR deficit counters, lane order — the fairness state the
    /// trace journal's dispatch samples carry
    /// ([`crate::obsv::DispatchPoint::lane_deficits`]).
    pub fn lane_deficits(&self) -> Vec<f64> {
        self.lanes.iter().map(|l| l.deficit).collect()
    }

    fn take(&mut self, i: usize) -> Popped<T> {
        let e = self.lanes[i].q.pop_front().expect("take on an empty lane");
        self.lanes[i].deficit -= e.cost;
        self.len -= 1;
        // Serves are the aging clock: blocked or empty pops ripen nothing.
        self.pops += 1;
        self.lanes[i].last_served = self.pops;
        if self.lanes[i].q.is_empty() {
            // forfeit unspent credit; keep debt on the books
            self.lanes[i].deficit = self.lanes[i].deficit.min(0.0);
            self.lanes[i].funded = false;
        }
        Popped::Item(TenantId(i), e.item)
    }
}

/// The dispatch stage's admission queue: weighted fair queueing, or the
/// plain FIFO it replaced (the QoS-off arm of the fairness ablation —
/// note FIFO suffers head-of-line blocking when its head tenant is
/// rate-capped, which is exactly the behaviour WFQ removes).
#[derive(Debug)]
pub enum AdmissionQueue<T> {
    Fifo(VecDeque<(TenantId, f64, T)>),
    Wfq(WfqQueue<T>),
}

impl<T> AdmissionQueue<T> {
    pub fn new(wfq: bool, weights: &[f64], aging_pops: u64) -> Self {
        if wfq {
            AdmissionQueue::Wfq(WfqQueue::new(weights, aging_pops))
        } else {
            AdmissionQueue::Fifo(VecDeque::new())
        }
    }

    pub fn len(&self) -> usize {
        match self {
            AdmissionQueue::Fifo(q) => q.len(),
            AdmissionQueue::Wfq(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&mut self, t: TenantId, cost: f64, item: T) {
        match self {
            AdmissionQueue::Fifo(q) => q.push_back((t, cost, item)),
            AdmissionQueue::Wfq(q) => q.push(t, cost, item),
        }
    }

    /// Re-enter a rescued request ahead of the backlog (see
    /// [`WfqQueue::push_front`]); on the FIFO arm it simply becomes the
    /// new global head.
    pub fn push_front(&mut self, t: TenantId, cost: f64, item: T) {
        match self {
            AdmissionQueue::Fifo(q) => q.push_front((t, cost, item)),
            AdmissionQueue::Wfq(q) => q.push_front(t, cost, item),
        }
    }

    /// Per-lane DRR deficits for trace sampling (empty on the FIFO arm,
    /// which keeps no fairness state).
    pub fn lane_deficits(&self) -> Vec<f64> {
        match self {
            AdmissionQueue::Fifo(_) => Vec::new(),
            AdmissionQueue::Wfq(q) => q.lane_deficits(),
        }
    }

    pub fn pop_eligible(&mut self, mut eligible: impl FnMut(TenantId, f64) -> bool) -> Popped<T> {
        match self {
            AdmissionQueue::Fifo(q) => match q.front() {
                None => Popped::Empty,
                Some(&(t, cost, _)) => {
                    if eligible(t, cost) {
                        let (t, _, item) = q.pop_front().unwrap();
                        Popped::Item(t, item)
                    } else {
                        Popped::Blocked(cost)
                    }
                }
            },
            AdmissionQueue::Wfq(q) => q.pop_eligible(eligible),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn ids<T: std::fmt::Debug>(q: &mut WfqQueue<T>, n: usize) -> Vec<usize> {
        (0..n)
            .map(|_| match q.pop() {
                Popped::Item(t, _) => t.0,
                other => panic!("expected an item, got {other:?}"),
            })
            .collect()
    }

    #[test]
    fn unit_quantum_interleaves_by_weight() {
        // weight 2 gets two pops for every one of weight 1, deterministic
        // with a unit quantum and unit costs.
        let mut q = WfqQueue::with_quantum(&[1.0, 2.0], u64::MAX, 1.0);
        for i in 0..12 {
            q.push(TenantId(0), 1.0, i);
            q.push(TenantId(1), 1.0, i);
        }
        let picks = ids(&mut q, 9);
        assert_eq!(picks, vec![0, 1, 1, 0, 1, 1, 0, 1, 1]);
    }

    #[test]
    fn flooding_lane_cannot_starve_a_light_one() {
        let mut q = WfqQueue::with_quantum(&[1.0, 1.0], u64::MAX, 1.0);
        for i in 0..100 {
            q.push(TenantId(0), 1.0, i); // the flood
        }
        for i in 0..3 {
            q.push(TenantId(1), 1.0, 100 + i);
        }
        // the light lane's three entries all serve within the first six
        // pops despite 100 queued ahead of them in arrival order
        let picks = ids(&mut q, 6);
        assert_eq!(picks.iter().filter(|&&t| t == 1).count(), 3, "{picks:?}");
    }

    #[test]
    fn service_shares_track_weights_in_cost_units() {
        // heterogeneous costs: shares measured in served cost, not pops
        let mut q = WfqQueue::with_quantum(&[1.0, 3.0], u64::MAX, 4.0);
        for i in 0..200 {
            q.push(TenantId(0), 8.0, i);
            q.push(TenantId(1), 8.0, i);
        }
        let mut served = [0.0f64; 2];
        for _ in 0..100 {
            match q.pop() {
                Popped::Item(t, _) => served[t.0] += 8.0,
                other => panic!("{other:?}"),
            }
        }
        let ratio = served[1] / served[0];
        assert!((2.0..4.5).contains(&ratio), "weight-3 lane got {ratio}× the weight-1 lane");
    }

    #[test]
    fn aging_promotes_an_overdue_entry_past_heavier_lanes() {
        // lane 0 is massively weighted; lane 1's single entry must still
        // serve once it has waited aging_pops pops.
        let mut q = WfqQueue::with_quantum(&[1000.0, 1.0], 4, 1.0);
        q.push(TenantId(1), 1.0, 999);
        for i in 0..50 {
            q.push(TenantId(0), 1.0, i);
        }
        let picks = ids(&mut q, 5);
        assert_eq!(picks[..4], [0, 0, 0, 0], "deficit favours lane 0 first");
        assert_eq!(picks[4], 1, "pop 5 is aging_pops past the entry's birth");
    }

    #[test]
    fn deep_floods_do_not_ripen_into_global_fifo() {
        // Regression: the promoter used to key on entry age alone, so any
        // backlog deeper than aging_pops was permanently "overdue" and
        // every pop served the flood in arrival order — WFQ collapsed to
        // FIFO exactly when it mattered. The lane-starvation condition
        // keeps DRR in charge: a constantly-served flood lane is never
        // promoted, and a late light entry still jumps the backlog.
        let mut q = WfqQueue::with_quantum(&[1.0, 1.0], 4, 1.0);
        for i in 0..40 {
            q.push(TenantId(0), 1.0, i);
        }
        // serve well past aging_pops so every flood head is "old"
        for _ in 0..10 {
            match q.pop() {
                Popped::Item(t, _) => assert_eq!(t.0, 0),
                other => panic!("{other:?}"),
            }
        }
        q.push(TenantId(1), 1.0, 999);
        let picks = ids(&mut q, 4);
        assert!(
            picks.contains(&1),
            "a deep flood must not FIFO-starve the light lane: {picks:?}"
        );
    }

    #[test]
    fn aging_debt_survives_an_emptied_lane() {
        // An aging promotion is served on credit (the lane's deficit goes
        // negative). Emptying the lane must forfeit only unspent credit —
        // a drip-feeding tenant cannot shed its debt by running dry.
        let mut q = WfqQueue::with_quantum(&[1.0, 1000.0], 4, 1.0);
        q.push(TenantId(0), 10.0, 'x'); // one expensive drip entry
        for _ in 0..50 {
            q.push(TenantId(1), 1.0, 'f'); // dominant backlogged peer
        }
        let mut drip_served = false;
        for _ in 0..20 {
            if let Popped::Item(TenantId(0), _) = q.pop() {
                drip_served = true;
                break;
            }
        }
        assert!(drip_served, "the promoter must eventually serve the drip");
        assert!(
            q.lane_deficit(0) < 0.0,
            "promotion debt must persist on the emptied lane, got {}",
            q.lane_deficit(0)
        );
    }

    #[test]
    fn aging_zero_is_global_fifo() {
        let mut q = WfqQueue::with_quantum(&[1.0, 100.0], 0, 1.0);
        q.push(TenantId(0), 1.0, 'a');
        q.push(TenantId(1), 1.0, 'b');
        q.push(TenantId(0), 1.0, 'c');
        assert_eq!(ids(&mut q, 3), vec![0, 1, 0], "arrival order, weights ignored");
    }

    #[test]
    fn ineligible_lanes_defer_without_blocking_others() {
        let mut q = WfqQueue::with_quantum(&[1.0, 1.0], u64::MAX, 1.0);
        q.push(TenantId(0), 1.0, 'a');
        q.push(TenantId(1), 1.0, 'b');
        // lane 0 rate-capped: lane 1 serves
        match q.pop_eligible(|t, _| t.0 != 0) {
            Popped::Item(t, item) => {
                assert_eq!(t.0, 1);
                assert_eq!(item, 'b');
            }
            other => panic!("{other:?}"),
        }
        // everything capped: Blocked with the refused head's cost as the
        // caller's sleep hint, nothing lost
        assert_eq!(q.pop_eligible(|_, _| false), Popped::Blocked(1.0));
        assert_eq!(q.len(), 1);
        match q.pop() {
            Popped::Item(t, item) => {
                assert_eq!(t.0, 0);
                assert_eq!(item, 'a');
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(q.pop(), Popped::Empty);
    }

    #[test]
    fn aging_respects_eligibility() {
        // an overdue entry whose tenant is rate-capped must not be
        // promoted — rate caps outrank the aging promoter.
        let mut q = WfqQueue::with_quantum(&[1.0, 1.0], 0, 1.0);
        q.push(TenantId(0), 1.0, 'a');
        q.push(TenantId(1), 1.0, 'b');
        match q.pop_eligible(|t, _| t.0 != 0) {
            Popped::Item(t, _) => assert_eq!(t.0, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn push_front_jumps_its_own_lane() {
        let mut q = WfqQueue::with_quantum(&[1.0], u64::MAX, 100.0);
        q.push(TenantId(0), 1.0, 'a');
        q.push(TenantId(0), 1.0, 'b');
        q.push_front(TenantId(0), 1.0, 'r'); // the rescue
        assert_eq!(q.len(), 3);
        let order: Vec<char> = (0..3)
            .map(|_| match q.pop() {
                Popped::Item(_, c) => c,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(order, vec!['r', 'a', 'b'], "rescue serves before the lane backlog");
    }

    #[test]
    fn push_front_ripens_for_the_aging_promoter_immediately() {
        // A rescue landing in a starved light lane behind a heavy flood:
        // its pre-aged birth stamp makes it overdue on the very next pop
        // instead of waiting out aging_pops serves like a fresh arrival.
        let mut q = WfqQueue::with_quantum(&[1000.0, 1.0], 4, 1.0);
        for i in 0..50 {
            q.push(TenantId(0), 1.0, i);
        }
        for _ in 0..10 {
            match q.pop() {
                Popped::Item(t, _) => assert_eq!(t.0, 0),
                other => panic!("{other:?}"),
            }
        }
        q.push_front(TenantId(1), 1.0, 999);
        match q.pop() {
            Popped::Item(t, item) => {
                assert_eq!((t.0, item), (1, 999), "rescue is promoted past the flood");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fifo_push_front_becomes_the_global_head() {
        let mut q: AdmissionQueue<char> = AdmissionQueue::new(false, &[1.0, 1.0], 0);
        q.push(TenantId(0), 1.0, 'a');
        q.push_front(TenantId(1), 1.0, 'r');
        match q.pop_eligible(|_, _| true) {
            Popped::Item(t, item) => assert_eq!((t.0, item), (1, 'r')),
            other => panic!("{other:?}"),
        }
        match q.pop_eligible(|_, _| true) {
            Popped::Item(t, item) => assert_eq!((t.0, item), (0, 'a')),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fifo_admission_queue_suffers_head_of_line_blocking() {
        let mut q: AdmissionQueue<char> = AdmissionQueue::new(false, &[1.0, 1.0], 0);
        q.push(TenantId(0), 1.0, 'a');
        q.push(TenantId(1), 1.0, 'b');
        // the WFQ arm would serve tenant 1 here; FIFO blocks behind the
        // capped head — the ablation's mechanism, pinned.
        assert_eq!(q.pop_eligible(|t, _| t.0 != 0), Popped::Blocked(1.0));
        match q.pop_eligible(|_, _| true) {
            Popped::Item(t, item) => {
                assert_eq!((t.0, item), (0, 'a'));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_wfq_conserves_items_and_lane_order() {
        forall(0xFA15, 200, |rng: &mut Rng| {
            let lanes = rng.range(1, 5) as usize;
            let weights: Vec<f64> = (0..lanes).map(|_| rng.f64_range(0.5, 4.0)).collect();
            let aging = if rng.chance(0.5) { rng.range(0, 20) } else { u64::MAX };
            let mut q = WfqQueue::with_quantum(&weights, aging, rng.f64_range(1.0, 16.0));
            let total = rng.range(1, 60) as usize;
            let mut pushed: Vec<Vec<u64>> = vec![Vec::new(); lanes];
            for item in 0..total as u64 {
                let lane = rng.below(lanes as u64) as usize;
                q.push(TenantId(lane), rng.f64_range(1.0, 12.0), item);
                pushed[lane].push(item);
            }
            assert_eq!(q.len(), total);
            let mut got: Vec<Vec<u64>> = vec![Vec::new(); lanes];
            for _ in 0..total {
                match q.pop() {
                    Popped::Item(t, item) => got[t.0].push(item),
                    other => panic!("lost an item: {other:?}"),
                }
            }
            assert_eq!(q.pop(), Popped::Empty);
            // every item surfaced exactly once, in FIFO order per lane
            assert_eq!(got, pushed);
        });
    }
}
