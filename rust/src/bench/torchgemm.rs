//! The paper's custom PyTorch matmul script (§1.3.4, §2.2.2d).
//!
//! A large `torch.matmul` loop timing FP32/FP16/FP64 throughput. Two
//! properties matter for reproduction:
//!
//! 1. PyTorch dispatches to prebuilt cuBLAS/cuDNN binaries —
//!    [`KernelSource::Lib`] — so recompiling *the script* with
//!    `-fmad=false` is meaningless, and §5.3 explains why patching PyTorch
//!    itself is impractical. The tool therefore only ever shows the
//!    *default* bars.
//! 2. PyTorch's FP16 matmul on a card without usable tensor cores falls
//!    back to scalar-half HFMA ("differences in how FP16 data is handled",
//!    §3.2) — 6.3 TFLOPS, not the half2 pipe's 50.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, KernelSource, MemPattern, Stmt, Traffic};
use crate::sim::{simulate_lowered, LoweredKernel, SimConfig};

use super::{Precision, ToolResult};

/// Matrix dimension of the script's square matmul.
const N: u64 = 8192;
/// Framework overhead leaves a bit more on the table than raw cuBLAS.
const TORCH_ISSUE_EFF: f64 = 0.97;

/// Build the matmul kernel PyTorch would dispatch for a precision.
pub fn kernel(precision: Precision) -> Kernel {
    let (class, elem) = match precision {
        Precision::Fp64 => (InstClass::Dfma, 8),
        // No usable tensor cores on the CMP: FP16 matmul falls back to
        // scalar HFMA. (On the A100 reference, torch would use HMMA; see
        // `kernel_tensor`.)
        Precision::Fp16Scalar | Precision::Fp16Half2 => (InstClass::Hfma, 2),
        _ => (InstClass::Ffma, 4),
    };
    let unique = 3 * N * N * elem;
    Kernel::new(format!("torch.matmul.{}", precision.name()), N * N, 256)
        .with_body(vec![
            Stmt::looped(N, vec![Stmt::op(class, 1)]),
            Stmt::op(InstClass::Imad, N / 16),
            Stmt::op(InstClass::Stg, 1),
        ])
        .with_traffic(Traffic {
            read_bytes: (2.0 * (N * N * elem) as f64 * (N as f64 / 128.0)) as u64,
            write_bytes: N * N * elem,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: crate::memhier::l2::hit_rate(unique, 64.0, 8 << 20),
        })
        .with_source(KernelSource::Lib)
}

/// The tensor-core HGEMM torch dispatches on healthy Ampere silicon.
pub fn kernel_tensor() -> Kernel {
    // One HMMA warp-instruction covers a 16×16×16 fragment = 8192 FLOPs;
    // priced at 512 FLOPs/inst in the rate table, so count 16 per k-step
    // of 16 per 256-thread tile… flattened: total HMMA insts =
    // 2·N³ / 512 FLOPs-per-inst, spread over N²/4 threads.
    let total_flops = 2 * N * N * N;
    let insts = total_flops / 512;
    let threads = N * N / 4;
    Kernel::new("torch.matmul.f16-tensor", threads, 256)
        .with_body(vec![Stmt::op(InstClass::HmmaF16, insts / threads)])
        .with_traffic(Traffic {
            read_bytes: (2.0 * (N * N * 2) as f64 * (N as f64 / 256.0)) as u64,
            write_bytes: N * N * 2,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: crate::memhier::l2::hit_rate(3 * N * N * 2, 128.0, 40 << 20),
        })
        .with_source(KernelSource::Lib)
}

/// Run the script's measurement for one precision.
pub fn run(dev: &DeviceSpec, precision: Precision) -> ToolResult {
    let cfg = SimConfig {
        issue_efficiency: TORCH_ISSUE_EFF,
        ..Default::default()
    };
    ToolResult {
        tool: "pytorch",
        case: precision.name().to_string(),
        timing: simulate_lowered(&LoweredKernel::lower(&kernel(precision)), dev, &cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;

    #[test]
    fn fp32_shows_only_the_crippled_default() {
        let dev = registry::cmp170hx();
        let t = run(&dev, Precision::Fp32).tflops();
        assert!(cal::check(&cal::FP32_DEFAULT_TFLOPS, t), "{t}");
    }

    #[test]
    fn fp16_is_scalar_not_half2() {
        // §3.2: "the FP16 performance reported by PyTorch and GPU-Burn is
        // only around 6.3 TFLOPS".
        let dev = registry::cmp170hx();
        let t = run(&dev, Precision::Fp16Scalar).tflops();
        assert!(cal::check(&cal::FP16_SCALAR_TFLOPS, t), "{t}");
        let half2 = crate::bench::openclbench::peak(
            &dev,
            Precision::Fp16Half2,
            crate::isa::pass::FmadPolicy::Fused,
        )
        .tflops();
        assert!(half2 / t > 7.0, "OpenCL half2 ({half2}) ≫ torch scalar ({t})");
    }

    #[test]
    fn fp64_matches_graph_3_3() {
        let dev = registry::cmp170hx();
        let t = run(&dev, Precision::Fp64).tflops();
        assert!(cal::check(&cal::FP64_DEFAULT_TFLOPS, t), "{t}");
    }

    #[test]
    fn tensor_path_works_on_a100_but_not_cmp() {
        let a100 = registry::a100_pcie();
        let cmp = registry::cmp170hx();
        let cfg = SimConfig::default();
        // One lowering, two devices — the lower-once/simulate-many shape.
        let lk = LoweredKernel::lower(&kernel_tensor());
        let on_a100 = simulate_lowered(&lk, &a100, &cfg);
        let on_cmp = simulate_lowered(&lk, &cmp, &cfg);
        assert!(on_a100.tflops() > 100.0, "{}", on_a100.tflops());
        assert!(on_cmp.time_s.is_infinite(), "CMP tensor cores are dark");
    }
}
