//! Self-healing policy knobs: rescue, retry/backoff, deadlines, probation.
//!
//! The mechanisms live in the coordinator (dispatch stage and node
//! workers); this module is the policy surface they read, kept in one
//! struct so the chaos suite and the CLI flip the same switches.

use std::time::Duration;

/// How the fleet heals around injected (or real) faults.
#[derive(Clone, Debug)]
pub struct RecoveryPolicy {
    /// Rescue in-flight sequences off a dead node: they re-enter the QoS
    /// queue and re-admit on a healthy card, replaying their generated
    /// tokens to a bit-identical state. This covers migration too: a
    /// sequence claimed from the shared park lot lives in the thief's
    /// in-flight set from the moment of the claim, so a dying migration
    /// target rescues it like any other live sequence, while entries
    /// still parked under a dead owner drain back through dispatch with
    /// their host-pool pages released. Off = the no-rescue ablation arm
    /// (a death loses its in-flight work with a terminal error).
    pub rescue: bool,
    /// Transient worker-side failures (KV pool momentarily full) bounce a
    /// request back to dispatch at most this many times before the error
    /// becomes terminal.
    pub max_retries: u32,
    /// Base delay of the exponential backoff between retry attempts.
    pub backoff: Duration,
    /// Per-request wall-clock budget, measured from submission. A request
    /// past its deadline is failed at the next dispatch or admission
    /// checkpoint rather than occupying a card. `None` = no deadline.
    pub deadline: Option<Duration>,
    /// A node readmitted by `mark_healthy` serves this many probe
    /// requests (one at a time) before routing trusts it with normal
    /// load; a failure during probation re-quarantines it. `0` = the
    /// legacy immediate readmission.
    pub probation_rounds: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            rescue: true,
            max_retries: 2,
            backoff: Duration::from_millis(2),
            deadline: None,
            probation_rounds: 2,
        }
    }
}

/// Exponential backoff: attempt 1 waits `base`, attempt 2 waits 2×, then
/// 4×, … capped at 64× so a stuck retry loop stays bounded.
pub fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    let shift = attempt.saturating_sub(1).min(6);
    base.saturating_mul(1u32 << shift)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_rescues_and_retries() {
        let p = RecoveryPolicy::default();
        assert!(p.rescue);
        assert!(p.max_retries > 0);
        assert!(p.backoff > Duration::ZERO);
        assert_eq!(p.deadline, None, "no deadline unless asked");
        assert!(p.probation_rounds > 0, "flapping cards must earn readmission");
    }

    #[test]
    fn backoff_doubles_per_attempt_and_caps() {
        let base = Duration::from_millis(2);
        assert_eq!(backoff_delay(base, 0), base, "attempt 0 clamps to base");
        assert_eq!(backoff_delay(base, 1), base);
        assert_eq!(backoff_delay(base, 2), base * 2);
        assert_eq!(backoff_delay(base, 3), base * 4);
        assert_eq!(backoff_delay(base, 7), base * 64);
        assert_eq!(backoff_delay(base, 40), base * 64, "cap holds far out");
    }
}
