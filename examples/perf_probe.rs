//! Perf probe for the serving hot path (EXPERIMENTS.md §Perf).
//!
//! Reports artifact compile time, prefill latency, and warm decode-step
//! latency. Run 3× and take the median — host timings are ±10% noisy.
//!
//! Run: `cargo run --release --example perf_probe`

use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = cmphx::runtime::ArtifactDir::discover()?;
    let t0 = Instant::now();
    let rt = cmphx::runtime::ModelRuntime::load(&dir)?;
    println!(
        "compile both executables: {:.2}s",
        t0.elapsed().as_secs_f64()
    );

    let prompt: Vec<i32> = (1..=rt.config.prefill_t as i32).collect();
    let t0 = Instant::now();
    let mut state = rt.prefill(&prompt)?;
    println!("prefill: {:.2}ms", t0.elapsed().as_secs_f64() * 1e3);

    // warm-up, then measure steady-state decode
    for _ in 0..4 {
        rt.decode(&mut state, 1)?;
    }
    let n = 32u32;
    let t0 = Instant::now();
    for _ in 0..n {
        rt.decode(&mut state, 1)?;
    }
    println!(
        "decode step: {:.2}ms",
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );
    Ok(())
}
