//! Radix-cache integration: KV retention beyond refcount zero, end to
//! end. A returning user's second turn resurrects the released
//! first-turn pages from the radix tree (bit-identical tokens, zero
//! re-prefill for the resident window); the `--no-kv-cache` ablation
//! re-prefills. Under a tight page budget the cached tier is reclaimed
//! for fresh admissions instead of refusing them.
//!
//! Every test skips (passes vacuously) when the AOT artifacts are
//! missing or PJRT is unavailable (the vendored stub xla crate) —
//! environments that cannot run the runtime at all.

use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{GenResponse, NodeConfig, Server, ServerConfig, ServerHandle};
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
mod common;
use common::artifact_dir;

/// One 170HX node; retention on or off (the `--no-kv-cache` ablation).
fn node1(retention: bool) -> ServerConfig {
    ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(200),
            kv_retention: retention,
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        nodes: vec![NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed)],
        ..Default::default()
    }
}

fn start(cfg: ServerConfig) -> Option<ServerHandle> {
    Some(Server::start(artifact_dir()?, cfg).unwrap())
}

/// Submit one prompt and wait for its response.
fn serve_one(server: &ServerHandle, prompt: Vec<i32>, tokens: usize) -> GenResponse {
    server
        .submit(prompt, tokens)
        .unwrap()
        .recv_timeout(Duration::from_secs(240))
        .unwrap()
}

#[test]
fn a_returning_user_resurrects_their_released_kv() {
    // Two serial turns of the same prompt. The first retires — releasing
    // its pages — before the second is admitted (retire releases before
    // it replies), so any prefix hit on turn two comes from the cached
    // tier, not live sharing. Retention on must resurrect the whole
    // prompt window; the ablation freed it and hits nothing.
    let prompt = vec![7, 3, 19, 4, 28, 11, 5, 61];

    let Some(server) = start(node1(true)) else { return };
    let first = serve_one(&server, prompt.clone(), 6);
    assert!(first.ok(), "{:?}", first.error);
    let second = serve_one(&server, prompt.clone(), 6);
    assert!(second.ok(), "{:?}", second.error);
    assert_eq!(
        first.tokens, second.tokens,
        "a resurrected prefix must decode bit-identically"
    );
    let m = server.shutdown();
    assert!(
        m.resurrected_blocks >= 1,
        "turn two must re-pin released blocks (resurrected={})",
        m.resurrected_blocks
    );
    assert!(m.prefix_hits >= 1, "resurrection counts as prefix hits");
    assert!(
        m.saved_prefill_resurrected_s > 0.0,
        "resurrected hits must credit the cache's share of saved prefill"
    );
    let hits_on = m.prefix_hits;

    let Some(server) = start(node1(false)) else { return };
    let r1 = serve_one(&server, prompt.clone(), 6);
    let r2 = serve_one(&server, prompt.clone(), 6);
    assert!(r1.ok() && r2.ok());
    assert_eq!(r1.tokens, first.tokens, "the ablation changes cost, not output");
    assert_eq!(r2.tokens, second.tokens);
    let m = server.shutdown();
    assert_eq!(
        m.resurrected_blocks, 0,
        "--no-kv-cache frees at refcount zero; nothing can resurrect"
    );
    assert!(
        hits_on > m.prefix_hits,
        "retention must win prefix hits serially: {hits_on} vs {}",
        m.prefix_hits
    );
}

#[test]
fn cache_pressure_reclaims_cached_blocks_instead_of_refusing_admission() {
    // A page budget that holds roughly one resident window: with
    // retention on, every retired prompt lingers as cache, so each new
    // distinct prompt can only be admitted by reclaiming the cached
    // tier. All requests must succeed, and the pager must report actual
    // reclaims — the cache yields under pressure rather than occupying.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = cmphx::runtime::goldens::config_usize(&dir, "prefill_t").unwrap();
    let mut cfg = node1(true);
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some(prefill_t + 16);
    let server = Server::start(dir, cfg).unwrap();
    for i in 0..3i32 {
        let prompt: Vec<i32> = (1..=8).map(|t| t * 7 + i * 100).collect();
        let r = serve_one(&server, prompt, 6);
        assert!(r.ok(), "request {i} must admit by reclaiming cache: {:?}", r.error);
    }
    let m = server.shutdown();
    assert_eq!(m.errors, 0);
    assert!(
        m.reclaimed_blocks >= 1,
        "distinct prompts under a tight budget must reclaim the cached tier"
    );
    assert!(m.cached_bytes > 0, "the last retiree's pages stay cached at shutdown");
}
