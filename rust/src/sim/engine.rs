//! The issue-rate / roofline timing engine.
//!
//! The hot entry point is [`simulate_lowered`], which consumes a cached
//! [`LoweredKernel`] — the device-independent lowering produced once per
//! kernel by [`LoweredKernel::lower`]. [`simulate`] is the convenience
//! wrapper for one-shot callers: it lowers and simulates in one call.
//! Sweeps (many kernels × many devices/configs) should lower each kernel
//! once and go through [`crate::sim::batch`].

use std::collections::BTreeMap;

use crate::device::DeviceSpec;
use crate::isa::class::{ALL_PIPES, N_PIPES};
use crate::isa::ir::Kernel;
use crate::sim::lowered::LoweredKernel;
use crate::sim::occupancy::Occupancy;

/// Engine knobs. Defaults model a well-tuned launch; benchmark ports adjust
/// `issue_efficiency` to reflect each tool's real launch pressure (this is
/// how the paper's CUDA-vs-OpenCL deltas arise).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Fraction of peak issue rate the kernel's schedule sustains
    /// (instruction dependencies, bank conflicts). 1.0 = perfectly greedy.
    pub issue_efficiency: f64,
    /// Fixed kernel launch overhead, seconds.
    pub launch_overhead_s: f64,
    /// Max resident threads per SM (GA100: 2048).
    pub max_threads_per_sm: u32,
    /// Overlap between compute and memory phases: 1.0 = perfectly hidden
    /// (roofline max), 0.0 = fully serialized (sum).
    pub overlap: f64,
    /// Skip wave quantization (used for *aggregate* kernels that stand in
    /// for a whole well-shaped launch sequence, e.g. one transformer
    /// layer's worth of GEMMs folded into a single instruction mix).
    pub ignore_occupancy: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            issue_efficiency: 0.98,
            launch_overhead_s: 5e-6,
            max_threads_per_sm: 2048,
            overlap: 1.0,
            ignore_occupancy: false,
        }
    }
}

/// Result of simulating one kernel launch.
#[derive(Clone, Debug)]
pub struct KernelTiming {
    pub name: String,
    /// End-to-end kernel time, seconds (post-DVFS).
    pub time_s: f64,
    /// Compute-limited time (max over pipes), pre-DVFS.
    pub compute_time_s: f64,
    /// Memory-limited time.
    pub memory_time_s: f64,
    /// Per-pipe busy time, pre-DVFS.
    pub pipe_times: BTreeMap<&'static str, f64>,
    /// Board power during the kernel, W.
    pub power_w: f64,
    /// Energy, joules.
    pub energy_j: f64,
    /// DVFS slowdown applied (1.0 = none).
    pub dvfs_derate: f64,
    /// Total FLOPs executed.
    pub flops: u64,
    /// Total integer ops executed.
    pub iops: u64,
    /// HBM bytes moved.
    pub bytes: f64,
}

impl KernelTiming {
    /// Achieved TFLOPS — what mixbench/OpenCL-Benchmark report.
    pub fn tflops(&self) -> f64 {
        self.flops as f64 / self.time_s / 1e12
    }

    /// Achieved TIOPs.
    pub fn tiops(&self) -> f64 {
        self.iops as f64 / self.time_s / 1e12
    }

    /// Achieved memory bandwidth, GB/s.
    pub fn gbps(&self) -> f64 {
        self.bytes / self.time_s / 1e9
    }

    /// Was the launch memory-bound?
    pub fn memory_bound(&self) -> bool {
        self.memory_time_s > self.compute_time_s
    }
}

/// Simulate one kernel launch on a device (one-shot convenience: lowers the
/// IR, then calls [`simulate_lowered`]). Callers that simulate the same
/// kernel more than once — across devices, throttles, or configs — should
/// lower once and use [`simulate_lowered`] or [`crate::sim::batch`].
pub fn simulate(kernel: &Kernel, dev: &DeviceSpec, cfg: &SimConfig) -> KernelTiming {
    simulate_lowered(&LoweredKernel::lower(kernel), dev, cfg)
}

/// Simulate one pre-lowered kernel launch on a device. No IR walk, no
/// traffic re-split, no energy re-weighting — everything device-independent
/// comes from the [`LoweredKernel`] cache.
pub fn simulate_lowered(lk: &LoweredKernel, dev: &DeviceSpec, cfg: &SimConfig) -> KernelTiming {
    // --- compute time: per-pipe serialization, cross-pipe overlap ---
    let mut pipe_acc = [0.0f64; N_PIPES];
    let mut pipe_used = [false; N_PIPES];
    for (class, count) in lk.mix.iter() {
        let rate = dev.effective_issue_rate(class) * cfg.issue_efficiency;
        let t = if rate > 0.0 {
            count as f64 / rate
        } else {
            f64::INFINITY // issuing to a fused-off pipe never completes
        };
        let p = class.pipe().index();
        pipe_acc[p] += t;
        pipe_used[p] = true;
    }
    let quant = if cfg.ignore_occupancy {
        1.0
    } else {
        Occupancy::new(lk.blocks, lk.block, dev.sms, cfg.max_threads_per_sm)
            .quantization_factor()
    };
    let compute_time = pipe_acc.iter().fold(0.0f64, |a, &b| a.max(b)) * quant;
    let pipe_times: BTreeMap<&'static str, f64> = ALL_PIPES
        .iter()
        .filter(|p| pipe_used[p.index()])
        .map(|&p| (p.name(), pipe_acc[p.index()]))
        .collect();

    // --- memory time (HBM/L2 split cached at lower time) ---
    let memory_time = dev
        .mem
        .transfer_time(lk.hbm_bytes, lk.l2_bytes, lk.traffic.pattern);

    // --- roofline combine + launch floor ---
    let serial = compute_time + memory_time;
    let overlapped = compute_time.max(memory_time);
    // Guard the blend: 0.0 × ∞ is NaN, and an unsupported (fused-off) pipe
    // must surface as an infinite duration, not a NaN-masked launch floor.
    let body = if serial.is_finite() {
        cfg.overlap * overlapped + (1.0 - cfg.overlap) * serial
    } else {
        f64::INFINITY
    };
    let raw_time = body.max(cfg.launch_overhead_s) + cfg.launch_overhead_s;

    // --- power / DVFS ---
    let insts = lk.mix.total() as f64;
    let (power_w, derate) = if raw_time.is_finite() {
        dev.power
            .board_power(lk.energy_ops, insts, lk.hbm_bytes, raw_time, dev.tdp_w)
    } else {
        (dev.power.static_w, 1.0)
    };
    let time_s = raw_time * derate;

    KernelTiming {
        name: lk.name.clone(),
        time_s,
        compute_time_s: compute_time,
        memory_time_s: memory_time,
        pipe_times,
        power_w,
        energy_j: power_w * time_s,
        dvfs_derate: derate,
        flops: lk.mix.flops(),
        iops: lk.mix.iops(),
        bytes: lk.bytes(),
    }
}

/// Convenience: estimate an L2 hit rate for a kernel that re-reads a
/// `unique_bytes` working set `reuse` times on this device.
pub fn l2_hint(dev: &DeviceSpec, unique_bytes: u64, reuse: f64) -> f64 {
    crate::memhier::l2::hit_rate(unique_bytes, reuse, dev.mem.l2_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry;
    use crate::device::ThrottleProfile;
    use crate::isa::class::InstClass::*;
    use crate::isa::ir::{MemPattern, Stmt, Traffic};
    use crate::isa::pass::{apply_fmad, FmadPolicy};
    use crate::testutil::{assert_close, forall, Rng};

    /// A pure-compute FP32 kernel big enough to hide launch overhead.
    fn fp32_kernel(threads: u64, fma_per_thread: u64) -> Kernel {
        Kernel::new("fp32", threads, 256)
            .with_body(vec![Stmt::looped(fma_per_thread, vec![Stmt::op(Ffma, 1)])])
            .with_traffic(Traffic::coalesced(threads * 4, threads * 4))
    }

    #[test]
    fn crippled_fp32_is_one_thirtysecond() {
        let dev = registry::cmp170hx();
        let k = fp32_kernel(70 * 2048 * 64, 4096);
        let t = simulate(&k, &dev, &SimConfig::default());
        // ~12.63/32 × issue_eff ≈ 0.387
        assert!(t.tflops() > 0.36 && t.tflops() < 0.41, "{}", t.tflops());
    }

    #[test]
    fn nofma_restores_fp32_to_half_theoretical() {
        let dev = registry::cmp170hx();
        let k = apply_fmad(&fp32_kernel(70 * 2048 * 64, 4096), FmadPolicy::Decomposed);
        let t = simulate(&k, &dev, &SimConfig::default());
        // peak 6.32 × eff; paper measures ~6.2
        assert!(t.tflops() > 5.9 && t.tflops() < 6.35, "{}", t.tflops());
    }

    #[test]
    fn headline_restore_factor_exceeds_fifteen() {
        let dev = registry::cmp170hx();
        let base = simulate(&fp32_kernel(70 * 2048 * 64, 4096), &dev, &SimConfig::default());
        let nofma = simulate(
            &apply_fmad(&fp32_kernel(70 * 2048 * 64, 4096), FmadPolicy::Decomposed),
            &dev,
            &SimConfig::default(),
        );
        let factor = nofma.tflops() / base.tflops();
        assert!(factor > 15.0 && factor < 16.5, "{factor}");
    }

    #[test]
    fn a100_fp32_hits_theoretical() {
        let dev = registry::a100_pcie();
        let k = fp32_kernel(108 * 2048 * 64, 4096);
        let t = simulate(&k, &dev, &SimConfig::default());
        // DVFS will cap near TDP; should still be > 15 TFLOPS.
        assert!(t.tflops() > 15.0, "{}", t.tflops());
    }

    #[test]
    fn memory_bound_kernel_reports_bandwidth() {
        let dev = registry::cmp170hx();
        let bytes: u64 = 8 << 30;
        let k = Kernel::new("stream", 1 << 22, 256)
            .with_body(vec![Stmt::op(Ldg, 16), Stmt::op(Stg, 16)])
            .with_traffic(Traffic::coalesced(bytes / 2, bytes / 2));
        let t = simulate(&k, &dev, &SimConfig::default());
        assert!(t.memory_bound());
        // 1493 × 0.88 ≈ 1314 GB/s
        assert!(t.gbps() > 1200.0 && t.gbps() < 1350.0, "{}", t.gbps());
    }

    #[test]
    fn tensor_kernel_on_cmp_never_completes_finite() {
        // Tensor pipe fused off → infinite compute time is surfaced as an
        // infinite duration, not a panic; callers treat it as "unsupported".
        let dev = registry::cmp170hx();
        let k = Kernel::new("hmma", 1 << 20, 256).with_body(vec![Stmt::op(HmmaF16, 64)]);
        let t = simulate(&k, &dev, &SimConfig::default());
        assert!(t.time_s.is_infinite());
    }

    #[test]
    fn dvfs_caps_power_at_tdp() {
        let dev = registry::a100_pcie();
        let k = fp32_kernel(108 * 2048 * 64, 65536);
        let t = simulate(&k, &dev, &SimConfig::default());
        assert!(t.power_w <= dev.tdp_w + 1e-9);
        assert!(t.dvfs_derate >= 1.0);
    }

    #[test]
    fn lowered_reuse_matches_oneshot_exactly() {
        // The lower-once path must be bit-identical to the lower-per-call
        // path, and the cached form must be reusable across devices and
        // configs without drift.
        let k = fp32_kernel(70 * 2048 * 64, 512);
        let lk = LoweredKernel::lower(&k);
        for dev in [registry::cmp170hx(), registry::a100_pcie()] {
            for cfg in [
                SimConfig::default(),
                SimConfig { overlap: 0.3, issue_efficiency: 0.5, ..Default::default() },
            ] {
                let oneshot = simulate(&k, &dev, &cfg);
                let cached = simulate_lowered(&lk, &dev, &cfg);
                assert_eq!(oneshot.time_s.to_bits(), cached.time_s.to_bits());
                assert_eq!(oneshot.power_w.to_bits(), cached.power_w.to_bits());
                assert_eq!(oneshot.flops, cached.flops);
                assert_eq!(oneshot.pipe_times, cached.pipe_times);
            }
        }
    }

    #[test]
    fn prop_more_throttle_never_faster() {
        // Monotonicity: lowering any class multiplier can only increase time.
        forall(0x51A1, 120, |rng: &mut Rng| {
            let dev = registry::cmp170hx();
            let mut tight = dev.clone();
            let mut p = ThrottleProfile::native();
            let mut q = ThrottleProfile::native();
            for c in [Ffma, Fmul, Fadd, Imad, Hfma2] {
                let m = rng.f64_range(0.05, 1.0);
                p.set(c, m);
                q.set(c, m * rng.f64_range(0.3, 1.0)); // q ≤ p classwise
            }
            let loose = dev.clone().with_throttle(p);
            tight = tight.with_throttle(q);
            let mut body = Vec::new();
            for c in [Ffma, Fmul, Imad, Hfma2] {
                body.push(Stmt::op(c, rng.range(1, 512)));
            }
            let k = Kernel::new("rand", rng.range(1 << 10, 1 << 22), 256).with_body(body);
            let lk = LoweredKernel::lower(&k);
            let t_loose = simulate_lowered(&lk, &loose, &SimConfig::default());
            let t_tight = simulate_lowered(&lk, &tight, &SimConfig::default());
            assert!(t_tight.time_s >= t_loose.time_s - 1e-12);
        });
    }

    #[test]
    fn prop_roofline_continuity_max_of_parts() {
        // With overlap=1, body time == max(compute, memory) (+overheads);
        // with overlap=0 it's the sum. Anything between is between.
        forall(0x0F, 150, |rng: &mut Rng| {
            let dev = registry::cmp170hx();
            let k = Kernel::new("k", rng.range(1 << 12, 1 << 24), 256)
                .with_body(vec![Stmt::op(Fmul, rng.range(1, 256))])
                .with_traffic(Traffic {
                    read_bytes: rng.range(1 << 20, 1 << 32),
                    write_bytes: rng.range(0, 1 << 30),
                    pattern: MemPattern::Coalesced,
                    l2_hit_rate: rng.f64_range(0.0, 0.9),
                });
            let lk = LoweredKernel::lower(&k);
            let cfg = |overlap| SimConfig { overlap, ..Default::default() };
            let t_max = simulate_lowered(&lk, &dev, &cfg(1.0));
            let t_mid = simulate_lowered(&lk, &dev, &cfg(0.5));
            let t_sum = simulate_lowered(&lk, &dev, &cfg(0.0));
            assert!(t_max.time_s <= t_mid.time_s + 1e-12);
            assert!(t_mid.time_s <= t_sum.time_s + 1e-12);
        });
    }

    #[test]
    fn energy_is_power_times_time() {
        let dev = registry::cmp170hx();
        let k = fp32_kernel(1 << 22, 512);
        let t = simulate(&k, &dev, &SimConfig::default());
        assert_close(t.energy_j, t.power_w * t.time_s, 1e-9);
    }
}
