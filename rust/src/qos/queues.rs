//! Per-node work queues with cross-node stealing.
//!
//! The fleet engine's dispatch stage used to hand each worker a private
//! mpsc channel — decide-once routing with no way to move a request once
//! queued. These queues replace the channels with shared, bounded,
//! lockable deques so an **idle** worker can pull the newest request off
//! the deepest peer queue ([`NodeQueues::steal_from`]) when its own runs
//! dry — capping tail latency when routing guessed wrong (the router's
//! weights are calibrated estimates, not measurements). Stealing takes
//! the *newest* entry (`pop_back`): the oldest waited longest behind its
//! chosen node and is about to be served there; the newest gains the most
//! from moving. A dead node's queue is still a valid steal source in the
//! window before its owner's drop guard [`NodeQueues::drain_node`]s it —
//! whatever is not rescued by then is dropped, so stranded clients fail
//! fast (their reply channel closes) instead of hanging forever.
//!
//! Producers see the same backpressure the channels gave: a bounded push
//! blocks while the target queue is at capacity, failing over only when
//! the consumer is gone (its `alive` flag cleared by the worker's drop
//! guard, the dispatch stage's dead-node signal).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of a blocking pop.
#[derive(Debug, PartialEq)]
pub enum WaitPop<T> {
    Item(T),
    TimedOut,
    /// The queue set is closed and this node's queue is drained.
    Closed,
}

struct Slot<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
    alive: AtomicBool,
}

/// One bounded queue per fleet node, plus liveness flags.
pub struct NodeQueues<T> {
    slots: Vec<Slot<T>>,
    open: AtomicBool,
}

impl<T> NodeQueues<T> {
    pub fn new(nodes: usize) -> Self {
        assert!(nodes > 0, "a fleet has at least one node");
        NodeQueues {
            slots: (0..nodes)
                .map(|_| Slot {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    alive: AtomicBool::new(true),
                })
                .collect(),
            open: AtomicBool::new(true),
        }
    }

    pub fn nodes(&self) -> usize {
        self.slots.len()
    }

    pub fn is_open(&self) -> bool {
        self.open.load(Ordering::Acquire)
    }

    pub fn alive(&self, node: usize) -> bool {
        self.slots[node].alive.load(Ordering::Acquire)
    }

    /// The worker's drop guard calls this; the dispatch stage treats a
    /// dead node like the old channels' failed send (reroute + exclude).
    pub fn mark_dead(&self, node: usize) {
        self.slots[node].alive.store(false, Ordering::Release);
        self.slots[node].cv.notify_all();
    }

    pub fn len(&self, node: usize) -> usize {
        self.slots[node].q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.q.lock().unwrap().is_empty())
    }

    /// Stop accepting work and wake every waiter; workers drain what was
    /// already queued, then see [`WaitPop::Closed`].
    pub fn close(&self) {
        self.open.store(false, Ordering::Release);
        for s in &self.slots {
            s.cv.notify_all();
        }
    }

    /// Blocking bounded push — the dispatch stage's send. Waits while the
    /// queue holds `cap` entries (backpressure propagates to the bounded
    /// submit channel), returning the request when the node has died so
    /// the caller can reroute it.
    pub fn push_bounded(&self, node: usize, item: T, cap: usize) -> Result<(), T> {
        let slot = &self.slots[node];
        let mut q = slot.q.lock().unwrap();
        loop {
            if !slot.alive.load(Ordering::Acquire) {
                return Err(item);
            }
            if q.len() < cap.max(1) {
                q.push_back(item);
                slot.cv.notify_all();
                return Ok(());
            }
            // Re-check liveness periodically: a worker that dies while we
            // wait would otherwise wedge the dispatch stage forever.
            let (guard, _) = slot
                .cv
                .wait_timeout(q, Duration::from_millis(10))
                .unwrap();
            q = guard;
        }
    }

    /// Non-blocking pop from the node's own queue.
    pub fn try_pop(&self, node: usize) -> Option<T> {
        let slot = &self.slots[node];
        let mut q = slot.q.lock().unwrap();
        let item = q.pop_front();
        if item.is_some() {
            // wake a producer blocked on the bound
            slot.cv.notify_all();
        }
        item
    }

    /// Blocking pop from the node's own queue, up to `timeout`.
    pub fn wait_pop(&self, node: usize, timeout: Duration) -> WaitPop<T> {
        let slot = &self.slots[node];
        let deadline = Instant::now() + timeout;
        let mut q = slot.q.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                slot.cv.notify_all();
                return WaitPop::Item(item);
            }
            if !self.is_open() {
                return WaitPop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return WaitPop::TimedOut;
            }
            let (guard, _) = slot.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Remove and return everything queued on one node — the worker-death
    /// path. The caller usually just drops the result: each orphaned
    /// request's reply channel closes with it, so waiting clients error
    /// out immediately (the old mpsc channels' behaviour) instead of
    /// blocking until server shutdown.
    pub fn drain_node(&self, node: usize) -> Vec<T> {
        let slot = &self.slots[node];
        let mut q = slot.q.lock().unwrap();
        let drained: Vec<T> = q.drain(..).collect();
        slot.cv.notify_all();
        drained
    }

    /// Atomically mark a node dead **and** take everything it had queued —
    /// the rescue path on node death. Doing both under one lock closes the
    /// race where a producer slips a request into the queue between the
    /// death flag and the drain (that request would be stranded forever).
    pub fn kill_node(&self, node: usize) -> Vec<T> {
        let slot = &self.slots[node];
        let mut q = slot.q.lock().unwrap();
        slot.alive.store(false, Ordering::Release);
        let drained: Vec<T> = q.drain(..).collect();
        slot.cv.notify_all();
        drained
    }

    /// Whether any live node's queue has a free slot under `cap` — the
    /// dispatch stage's pop-on-demand gate (defer the fair-queue decision
    /// until a node can actually take the request). A fully-dead queue
    /// set reports space so the dispatch stage reaches its shedding path
    /// instead of waiting forever.
    pub fn any_space(&self, cap: usize) -> bool {
        let mut any_alive = false;
        for s in &self.slots {
            if s.alive.load(Ordering::Acquire) {
                any_alive = true;
                if s.q.lock().unwrap().len() < cap.max(1) {
                    return true;
                }
            }
        }
        !any_alive
    }

    /// Inspect the head of one node's queue without popping it — the
    /// prefix-aware admission gate peeks the next request's prompt
    /// against the pager's resident prefix before deciding whether the
    /// capacity edge can actually hold it. The closure runs under the
    /// queue lock, so keep it cheap (hashing a window, not serving it).
    /// `None` when the queue is empty.
    pub fn peek_with<R>(&self, node: usize, f: impl FnOnce(&T) -> R) -> Option<R> {
        let q = self.slots[node].q.lock().unwrap();
        q.front().map(f)
    }

    /// Pop the best-scoring *eligible* entry among the first `k` queued on
    /// one node — the bounded admission scan (`--admit-scan`). The scorer
    /// runs under the queue lock for each inspected entry, so keep it
    /// cheap (a radix descent, not a serve); `None` marks an entry
    /// ineligible (it stays queued in place). Ties break to the earliest
    /// position and `k` floors at 1, so a uniform scorer degrades to
    /// [`try_pop`]'s strict FIFO: fair-queue order is perturbed by at
    /// most `k - 1` positions, and only when a deeper entry genuinely
    /// scores higher. Returns `None` when no inspected entry is eligible.
    ///
    /// [`try_pop`]: NodeQueues::try_pop
    pub fn pop_best_within(
        &self,
        node: usize,
        k: usize,
        score: impl Fn(&T) -> Option<usize>,
    ) -> Option<T> {
        let slot = &self.slots[node];
        let mut q = slot.q.lock().unwrap();
        let depth = k.max(1).min(q.len());
        let (_, std::cmp::Reverse(best)) = q
            .iter()
            .take(depth)
            .enumerate()
            .filter_map(|(i, item)| score(item).map(|s| (s, std::cmp::Reverse(i))))
            .max()?;
        let item = q.remove(best);
        if item.is_some() {
            slot.cv.notify_all();
        }
        item
    }

    /// Steal the newest entry from the deepest peer queue (ties to the
    /// lowest index). Returns `(victim_node, item)`. Peers are scanned by
    /// momentary depth; dead nodes' queues are eligible victims (rescue).
    pub fn steal_from(&self, thief: usize) -> Option<(usize, T)> {
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != thief)
            .map(|(i, s)| (s.q.lock().unwrap().len(), i))
            .filter(|&(len, _)| len > 0)
            .max_by_key(|&(len, i)| (len, std::cmp::Reverse(i)))?
            .1;
        let slot = &self.slots[victim];
        let mut q = slot.q.lock().unwrap();
        // the queue may have drained between the scan and this lock
        let item = q.pop_back()?;
        slot.cv.notify_all();
        Some((victim, item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_roundtrip_per_node() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        q.push_bounded(0, 1, 8).unwrap();
        q.push_bounded(0, 2, 8).unwrap();
        q.push_bounded(1, 9, 8).unwrap();
        assert_eq!(q.len(0), 2);
        assert_eq!(q.try_pop(0), Some(1), "own queue is FIFO");
        assert_eq!(q.try_pop(1), Some(9));
        assert_eq!(q.try_pop(1), None);
    }

    #[test]
    fn steal_takes_the_newest_from_the_deepest_peer() {
        let q: NodeQueues<u32> = NodeQueues::new(3);
        for v in [1, 2] {
            q.push_bounded(0, v, 8).unwrap();
        }
        for v in [10, 11, 12] {
            q.push_bounded(2, v, 8).unwrap();
        }
        // node 1 idles; node 2 is deepest; the newest entry moves
        assert_eq!(q.steal_from(1), Some((2, 12)));
        // depths now tie at 2 — ties break to the lowest index
        assert_eq!(q.steal_from(1), Some((0, 2)));
        // a thief never steals from itself
        q.push_bounded(1, 99, 8).unwrap();
        assert_eq!(q.steal_from(0), Some((2, 11)));
        assert_eq!(q.len(1), 1);
    }

    #[test]
    fn steal_returns_none_when_peers_are_empty() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        q.push_bounded(0, 7, 8).unwrap();
        assert_eq!(q.steal_from(0), None, "own work is not steal-able");
        assert_eq!(q.steal_from(1), Some((0, 7)));
        assert_eq!(q.steal_from(1), None);
    }

    #[test]
    fn dead_nodes_reject_pushes_but_still_get_drained() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        q.push_bounded(0, 5, 8).unwrap();
        q.mark_dead(0);
        assert!(!q.alive(0));
        assert_eq!(q.push_bounded(0, 6, 8), Err(6), "dead node bounces the push");
        // the stranded entry is rescued by a stealing peer
        assert_eq!(q.steal_from(1), Some((0, 5)));
    }

    #[test]
    fn drain_node_empties_the_queue_and_returns_the_items() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        for v in [1, 2, 3] {
            q.push_bounded(0, v, 8).unwrap();
        }
        q.mark_dead(0);
        assert_eq!(q.drain_node(0), vec![1, 2, 3]);
        assert_eq!(q.len(0), 0);
        assert_eq!(q.steal_from(1), None, "nothing left to rescue");
        assert_eq!(q.drain_node(0), Vec::<u32>::new());
    }

    #[test]
    fn kill_node_marks_dead_and_drains_in_one_step() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        for v in [1, 2, 3] {
            q.push_bounded(0, v, 8).unwrap();
        }
        assert_eq!(q.kill_node(0), vec![1, 2, 3]);
        assert!(!q.alive(0), "killed node is dead");
        assert_eq!(q.len(0), 0);
        assert_eq!(q.push_bounded(0, 4, 8), Err(4), "no new work lands on the corpse");
        assert_eq!(q.kill_node(0), Vec::<u32>::new(), "second kill is a no-op");
        // the peer is untouched
        q.push_bounded(1, 9, 8).unwrap();
        assert_eq!(q.try_pop(1), Some(9));
    }

    #[test]
    fn any_space_gates_on_live_queues_only() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        assert!(q.any_space(1));
        q.push_bounded(0, 1, 2).unwrap();
        q.push_bounded(1, 2, 2).unwrap();
        assert!(!q.any_space(1), "both queues at the bound");
        assert!(q.any_space(2));
        q.mark_dead(1);
        q.push_bounded(0, 3, 2).unwrap();
        assert!(!q.any_space(2), "a dead node's queue is not space");
        // fully dead: report space so the dispatcher reaches shedding
        q.mark_dead(0);
        assert!(q.any_space(2));
    }

    #[test]
    fn peek_with_reads_the_head_without_popping() {
        let q: NodeQueues<u32> = NodeQueues::new(2);
        assert_eq!(q.peek_with(0, |v| *v), None, "empty queue has no head");
        for v in [7, 8] {
            q.push_bounded(0, v, 8).unwrap();
        }
        assert_eq!(q.peek_with(0, |v| *v), Some(7), "peek sees the FIFO head");
        assert_eq!(q.len(0), 2, "peeking must not consume");
        assert_eq!(q.try_pop(0), Some(7), "the peeked head is what pops next");
        assert_eq!(q.peek_with(0, |v| v * 10), Some(80), "closure maps the head");
        assert_eq!(q.peek_with(1, |v| *v), None, "peers' queues are separate");
    }

    #[test]
    fn pop_best_within_scans_a_bounded_window_and_keeps_fifo_on_ties() {
        let q: NodeQueues<u32> = NodeQueues::new(1);
        assert_eq!(q.pop_best_within(0, 4, |v| Some(*v as usize)), None);
        // queue: [3, 1, 9, 2, 50] — 50 sits beyond a K=4 window
        for v in [3, 1, 9, 2, 50] {
            q.push_bounded(0, v, 8).unwrap();
        }
        // the best match inside the window pops, not the head and not the
        // out-of-window 50
        assert_eq!(q.pop_best_within(0, 4, |v| Some(*v as usize)), Some(9));
        // a uniform scorer is strict FIFO: the fair-queue (WFQ lane /
        // aging) order the dispatcher enqueued is respected when no entry
        // genuinely matches deeper than another
        assert_eq!(q.pop_best_within(0, 4, |_| Some(0)), Some(3));
        // ineligible entries (scorer None) are skipped but never popped,
        // and never lose their position
        assert_eq!(q.pop_best_within(0, 4, |v| (*v > 10).then_some(0)), Some(50));
        assert_eq!(q.pop_best_within(0, 4, |v| (*v > 10).then_some(0)), None);
        assert_eq!(q.len(0), 2, "ineligible entries stay queued");
        // K floors at 1 — head-only, the PR 7 peek behaviour
        assert_eq!(q.pop_best_within(0, 0, |v| Some(*v as usize)), Some(1));
        // the window clamps to the queue depth
        assert_eq!(q.pop_best_within(0, 16, |v| Some(*v as usize)), Some(2));
        assert_eq!(q.pop_best_within(0, 4, |v| Some(*v as usize)), None);
    }

    #[test]
    fn bounded_push_blocks_until_a_pop_frees_a_slot() {
        let q: Arc<NodeQueues<u32>> = Arc::new(NodeQueues::new(1));
        q.push_bounded(0, 1, 1).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push_bounded(0, 2, 1));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!producer.is_finished(), "push past the bound must block");
        assert_eq!(q.try_pop(0), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(q.try_pop(0), Some(2));
    }

    #[test]
    fn wait_pop_times_out_then_sees_items_then_closure() {
        let q: Arc<NodeQueues<u32>> = Arc::new(NodeQueues::new(1));
        assert_eq!(q.wait_pop(0, Duration::from_millis(10)), WaitPop::TimedOut);
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push_bounded(0, 42, 8).unwrap();
            q2.close();
        });
        assert_eq!(q.wait_pop(0, Duration::from_secs(5)), WaitPop::Item(42));
        t.join().unwrap();
        // closed and drained: no more blocking
        assert_eq!(q.wait_pop(0, Duration::from_secs(5)), WaitPop::Closed);
    }

    #[test]
    fn close_drains_queued_work_before_reporting_closed() {
        let q: NodeQueues<u32> = NodeQueues::new(1);
        q.push_bounded(0, 1, 8).unwrap();
        q.close();
        assert_eq!(q.wait_pop(0, Duration::from_millis(5)), WaitPop::Item(1));
        assert_eq!(q.wait_pop(0, Duration::from_millis(5)), WaitPop::Closed);
    }
}
