//! End-to-end serving benchmark: the full L1→L2→L3 stack under load.
//!
//! Compiles the AOT artifacts, then measures served throughput and latency
//! percentiles at several concurrency caps — the batching-policy ablation
//! DESIGN.md calls out — plus the simulated device time for the same token
//! schedule. A fleet section runs a heterogeneous 170HX + 90HX fleet under
//! continuous batching and answers the §6.2 question: how many recycled
//! cards replace one A100, at what energy cost. A **prefix ablation**
//! serves an identical-prompt burst with block-hash prefix sharing on vs
//! off, and the page-pressure ablation runs preempt-and-requeue with the
//! PCIe-priced swap path off and on. A final **fairness ablation** floods
//! a 2-card fleet with one tenant at ~10× another's demand and measures
//! the light tenant's p99 and Jain's index with the QoS layer (WFQ + work
//! stealing) on vs off, recording the result as the `serve_fairness` row
//! of `BENCH_sim_throughput.json` (row-owned read-modify-write via
//! [`cmphx::bench_harness::upsert_bench_row`]). A **fabric ablation**
//! compares prefix-affine routing and swap–decode overlap against their
//! `--no-affinity`/`--no-overlap` baselines, owning the `serve_fabric`
//! row, and a **radix-cache ablation** serves a returning-user workload
//! with KV retention on vs the `--no-kv-cache` frees-at-refcount-zero
//! baseline, owning the `serve_radix_cache` row. A **trace ablation**
//! reruns the base workload with the span tracer on vs off and asserts the
//! analytic overhead bound — simulated goodput bit-identical, because every
//! trace stamp reads the simulated clock — owning the
//! `serve_trace_overhead` row. An **open-loop overload sweep** runs first
//! on the pure discrete-event fleet model — admission control vs the
//! `--no-admission-control` ablation, calm and under seeded chaos, at six
//! offered-load points through the latency knee — owning the
//! `serve_openloop` row; it needs no artifacts, so it records real numbers
//! everywhere. Everything else requires `make artifacts`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cmphx::bench_harness::upsert_bench_row;
use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{jain_index, NodeConfig, RoutePolicy, Server, ServerConfig, ServerHandle};
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::quant;
use cmphx::market::tco;
use cmphx::qos::TenantSpec;
use cmphx::runtime::ArtifactDir;

const REQUESTS: usize = 12;
const TOKENS: usize = 8;

fn artifacts() -> anyhow::Result<ArtifactDir> {
    ArtifactDir::open(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn config(max_batch: usize, step_policy: StepPolicy) -> ServerConfig {
    ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(3),
            ..BatchPolicy::default()
        },
        step_policy,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    }
}

fn submit_workload(server: &cmphx::coordinator::ServerHandle, n: usize) -> anyhow::Result<()> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, TOKENS).unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv()?;
        assert!(resp.ok(), "{:?}", resp.error);
    }
    Ok(())
}

fn run_once(max_batch: usize, step_policy: StepPolicy) -> anyhow::Result<()> {
    let server = Server::start(artifacts()?, config(max_batch, step_policy))?;
    let t0 = Instant::now();
    submit_workload(&server, REQUESTS)?;
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "batch={max_batch:<2} policy={step_policy:?}: {} tok in {wall:.2}s → {:>6.1} tok/s | p50 {:>6.1}ms p99 {:>6.1}ms | sim {:>6.1}ms {:>5.1} tok/J",
        m.tokens_out,
        m.tokens_out as f64 / wall,
        m.latency_pct(0.5).unwrap_or(0.0) * 1e3,
        m.latency_pct(0.99).unwrap_or(0.0) * 1e3,
        m.simulated_device_s * 1e3,
        m.sim_tokens_per_joule(),
    );
    Ok(())
}

fn run_fleet() -> anyhow::Result<()> {
    let mut cfg = config(4, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::WeightedThroughput;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp90hx(), FmadPolicy::Decomposed),
    ];
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    submit_workload(&server, 2 * REQUESTS)?;
    let wall = t0.elapsed().as_secs_f64();
    let fm = server.shutdown_fleet();
    println!("served {} requests in {wall:.2}s wall", 2 * REQUESTS);
    print!("{}", fm.render());

    // The §6.2 answer. The replacement ratios compare decode operating
    // points on BOTH sides (the A100 reference is decode-only; mixing in
    // the serving basis — prefill charged at TDP — would bias the numbers
    // against the recycled cards). The *measured* serving rate feeds the
    // fleet-sizing line instead, where both sides share the same basis.
    let bench = LlamaBench::default();
    let a100 = bench.run(&registry::a100_pcie(), &quant::Q8_0, FmadPolicy::Fused);
    for (name, m) in &fm.nodes {
        if m.tokens_out == 0 {
            continue;
        }
        let dev = registry::by_name(name).expect("fleet node in registry");
        // same policy the fleet nodes were configured with above
        let row = bench.run(&dev, &quant::Q8_0, FmadPolicy::Decomposed);
        let rep = tco::a100_replacement(
            &dev,
            row.decode_tps,
            row.decode_power_w,
            a100.decode_tps,
            a100.decode_power_w,
        );
        let plan =
            tco::fleet_for_measured_throughput(&dev, m.sim_tokens_per_sec(), a100.decode_tps);
        println!(
            "{name}: {} cards ≈ one A100 on decode ({:.0}% capex, {:.1}× power, {:.2}× J/token); \
             at the measured serving rate ({:.0} tok/s/card incl. prefill) {} cards",
            rep.cards_per_a100,
            rep.capex_ratio * 100.0,
            rep.power_ratio,
            rep.energy_per_token_ratio,
            m.sim_tokens_per_sec(),
            plan.cards,
        );
    }
    Ok(())
}

/// Serve a long + shorts mix under a deliberately tight page pool, with
/// and without preemption, and with swap-based comebacks armed — the
/// paged-KV ablation: how much recompute tax does preempt-and-requeue
/// pay to keep short requests completing, and how much of it does the
/// PCIe-priced swap path buy back?
fn run_pressure(preempt: bool, swap: bool) -> anyhow::Result<()> {
    const LONG: usize = 24;
    const SHORT: usize = 6;
    let dir = artifacts()?;
    let prefill_t = cmphx::runtime::goldens::config_usize(&dir, "prefill_t")?;
    let mut cfg = config(2, StepPolicy::ShortestFirst);
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget =
        Some((prefill_t + LONG - 1).max(2 * (prefill_t + SHORT)));
    cfg.batch.preempt = preempt;
    cfg.batch.swap = swap;
    let server = Server::start(dir, cfg)?;
    let t0 = Instant::now();
    let rx_long = server.submit(vec![3, 1, 4, 1, 5, 9, 2, 6], LONG)?;
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, SHORT).unwrap()
        })
        .collect();
    let mut served = 0usize;
    for rx in rx_shorts.into_iter().chain(std::iter::once(rx_long)) {
        if rx.recv()?.ok() {
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "preempt={preempt:<5} swap={swap:<5}: {served}/5 served, {} tok in {wall:.2}s | \
         evicted={} resumed={} wasted_sim={:.1}ms | swapped out={} in={} link_s={:.1}ms \
         saved_sim={:.1}ms | errors={}",
        m.tokens_out,
        m.preemptions,
        m.resumes,
        m.wasted_prefill_s * 1e3,
        m.swap_outs,
        m.swap_ins,
        m.swap_transfer_s * 1e3,
        m.saved_recompute_s * 1e3,
        m.errors,
    );
    Ok(())
}

/// Identical-prompt burst with the prefix cache on vs off: every request
/// shares the whole prompt window, so the cached arm should report block
/// hits (and saved simulated prefill) where the ablation arm allocates
/// every block fresh.
fn run_prefix_ablation(prefix_cache: bool) -> anyhow::Result<()> {
    let mut cfg = config(4, StepPolicy::RoundRobin);
    cfg.batch.prefix_cache = prefix_cache;
    let server = Server::start(artifacts()?, cfg)?;
    let prompt = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let rxs: Vec<_> =
        (0..REQUESTS).map(|_| server.submit(prompt.clone(), TOKENS).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv()?;
        assert!(resp.ok(), "{:?}", resp.error);
    }
    let m = server.shutdown();
    println!(
        "prefix_cache={prefix_cache:<5}: {} requests | block hits={} misses={} ({:.0}%) \
         cow={} saved_sim={:.2}ms",
        m.requests,
        m.prefix_hits,
        m.prefix_misses,
        m.prefix_hit_rate() * 100.0,
        m.cow_copies,
        m.saved_prefill_s * 1e3,
    );
    Ok(())
}

/// The fairness flood workload: a light tenant keeping 2 long requests in
/// flight and a heavy tenant keeping ~10× the light tenant's token demand
/// outstanding as short requests, on a 2-card 170HX fleet with
/// single-sequence nodes (so wall latency compares cleanly across runs).
/// Closed-loop, so both tenants stay backlogged for the whole measured
/// window and the per-tenant token split *is* the service split. Returns
/// (light p99 seconds, Jain's index over per-tenant tokens served while
/// the light tenant was active).
fn run_fairness_once(qos: bool) -> anyhow::Result<(f64, f64)> {
    const LIGHT_N: usize = 8;
    const LIGHT_OUT: usize = 2;
    const LIGHT_TOK: usize = 20;
    // ~10× the light tenant's outstanding token demand (2×20), as shorts
    const HEAVY_OUT: usize = 48;
    const TOK: usize = 8; // heavy request length
    let mut cfg = config(1, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::WeightedThroughput;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    cfg.qos.enabled = qos;
    cfg.qos.steal = qos;
    cfg.qos.node_queue_depth = 1;
    cfg.qos.tenants =
        vec![TenantSpec::new("light", 1.0), TenantSpec::new("heavy", 1.0)];
    let server = Arc::new(Server::start(artifacts()?, cfg)?);
    let light = server.tenant_id("light").unwrap();
    let heavy = server.tenant_id("heavy").unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let heavy_tokens = Arc::new(AtomicU64::new(0));
    let flood = {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let heavy_tokens = Arc::clone(&heavy_tokens);
        std::thread::spawn(move || {
            let submit = |i: usize| {
                let prompt: Vec<i32> =
                    (1..=8).map(|t| (t * (i as i32 + 11)) % 500 + 1).collect();
                server.submit_as(heavy, prompt, TOK).ok()
            };
            let mut next = 0usize;
            let mut pending = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                while pending.len() < HEAVY_OUT {
                    match submit(next) {
                        Some(rx) => pending.push(rx),
                        None => break, // backpressure: retry after the poll
                    }
                    next += 1;
                }
                pending.retain(|rx| match rx.try_recv() {
                    Ok(resp) => {
                        if resp.ok() && !stop.load(Ordering::Relaxed) {
                            heavy_tokens.fetch_add(resp.tokens.len() as u64, Ordering::Relaxed);
                        }
                        false
                    }
                    Err(_) => true,
                });
                std::thread::sleep(Duration::from_millis(1));
            }
            drop(pending); // cancel whatever is still in flight
        })
    };

    // Light tenant: closed loop of LIGHT_OUT outstanding, LIGHT_N total.
    let mut latencies = Vec::with_capacity(LIGHT_N);
    let mut light_tokens = 0u64;
    let mut inflight = std::collections::VecDeque::new();
    let mut submitted = 0usize;
    while light_tokens < (LIGHT_N * LIGHT_TOK) as u64 {
        while inflight.len() < LIGHT_OUT && submitted < LIGHT_N {
            let prompt: Vec<i32> =
                (1..=8).map(|t| (t * (submitted as i32 + 2)) % 500 + 1).collect();
            match server.submit_as(light, prompt, LIGHT_TOK) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        let rx = inflight.pop_front().expect("light loop always has work");
        let resp = rx.recv()?;
        anyhow::ensure!(resp.ok(), "light request failed: {:?}", resp.error);
        light_tokens += resp.tokens.len() as u64;
        latencies.push(resp.latency_s());
    }
    stop.store(true, Ordering::Relaxed);
    let heavy_window_tokens = heavy_tokens.load(Ordering::Relaxed);
    flood.join().unwrap();
    drop(server);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = latencies[((latencies.len() as f64 - 1.0) * 0.99).round() as usize];
    let jain = jain_index(&[light_tokens as f64, heavy_window_tokens as f64]);
    Ok((p99, jain))
}

/// Light tenant alone on the same fleet — the solo-p99 baseline the
/// fairness acceptance bound is phrased against.
fn run_light_solo() -> anyhow::Result<f64> {
    let mut cfg = config(1, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::WeightedThroughput;
    cfg.qos.node_queue_depth = 1;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    let server: ServerHandle = Server::start(artifacts()?, cfg)?;
    let mut latencies = Vec::new();
    for i in 0..8usize {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
        let rx = server.submit(prompt, 20)?;
        let resp = rx.recv()?;
        anyhow::ensure!(resp.ok(), "{:?}", resp.error);
        latencies.push(resp.latency_s());
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(latencies[((latencies.len() as f64 - 1.0) * 0.99).round() as usize])
}

fn run_fairness() -> anyhow::Result<()> {
    let solo_p99 = run_light_solo()?;
    let (on_p99, on_jain) = run_fairness_once(true)?;
    let (off_p99, off_jain) = run_fairness_once(false)?;
    println!("light solo           : p99 {:>7.1}ms", solo_p99 * 1e3);
    println!(
        "qos on  (wfq+steal)  : light p99 {:>7.1}ms ({:>4.1}× solo)  jain {:.3}",
        on_p99 * 1e3,
        on_p99 / solo_p99,
        on_jain
    );
    println!(
        "qos off (fifo)       : light p99 {:>7.1}ms ({:>4.1}× solo)  jain {:.3}",
        off_p99 * 1e3,
        off_p99 / solo_p99,
        off_jain
    );
    let row = format!(
        "{{\n    \"workload\": \"2-card 170HX fleet, heavy tenant at ~10x the light tenant's \
         outstanding demand, closed-loop\",\n    \
         \"light_solo_p99_ms\": {:.3},\n    \
         \"qos_on_light_p99_ms\": {:.3},\n    \
         \"qos_on_jain\": {:.4},\n    \
         \"qos_off_light_p99_ms\": {:.3},\n    \
         \"qos_off_jain\": {:.4}\n  }}",
        solo_p99 * 1e3,
        on_p99 * 1e3,
        on_jain,
        off_p99 * 1e3,
        off_jain,
    );
    // Row-owned read-modify-write: only this bench's row changes, so it
    // never clobbers bench_sim_throughput's rows (or vice versa).
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_fairness", &row);
    Ok(())
}

/// One fabric-routing arm: three identical-prompt families served
/// serially over a 2-card 170HX fleet with prefix-affine routing on or
/// off. Affinity concentrates each family on the card already holding
/// its pages (the directory publishes resident chains every round), so
/// fleet-wide prefix block hits rise and repeated prefills vanish; the
/// ablation spreads every family across both cards and pays the misses.
/// Returns (prefix block hits, affine routes, wall s, served tok/s).
fn run_fabric_once(affinity: bool) -> anyhow::Result<(u64, u64, f64, f64)> {
    let mut cfg = config(2, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::RoundRobin;
    cfg.affinity = affinity;
    cfg.qos.steal = false; // isolate routing from work movement
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    let mut tokens = 0u64;
    for i in 0..REQUESTS {
        let family = (i % 3) as i32;
        let prompt: Vec<i32> = (1..=8).map(|t| t * 7 + family * 100).collect();
        let resp = server.submit(prompt, TOKENS)?.recv()?;
        anyhow::ensure!(resp.ok(), "fabric request failed: {:?}", resp.error);
        tokens += resp.tokens.len() as u64;
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown_fleet().total();
    Ok((m.prefix_hits, m.affine_routes, wall, tokens as f64 / wall))
}

/// One swap-overlap arm: the page-pressure workload with the PCIe swap
/// path armed and transfer/decode overlap on or off. Returns the swap
/// ledger split: (transfer s, stalled s, overlapped s).
fn run_fabric_overlap(overlap: bool) -> anyhow::Result<(f64, f64, f64)> {
    const LONG: usize = 24;
    const SHORT: usize = 6;
    let dir = artifacts()?;
    let prefill_t = cmphx::runtime::goldens::config_usize(&dir, "prefill_t")?;
    let mut cfg = config(2, StepPolicy::ShortestFirst);
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some((prefill_t + LONG - 1).max(2 * prefill_t + 4));
    cfg.batch.swap = true;
    cfg.overlap = overlap;
    let server = Server::start(dir, cfg)?;
    let rx_long = server.submit(vec![3, 1, 4, 1, 5, 9, 2, 6], LONG)?;
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, SHORT).unwrap()
        })
        .collect();
    for rx in rx_shorts.into_iter().chain(std::iter::once(rx_long)) {
        let _ = rx.recv()?;
    }
    let m = server.shutdown();
    Ok((m.swap_transfer_s, m.swap_stalled_s, m.swap_overlapped_s))
}

/// The KV-fabric ablations as a bench row: prefix-affine routing vs the
/// plain fleet policy, and swap–decode overlap vs serial transfer
/// charging. Recorded as the `serve_fabric` row of
/// `BENCH_sim_throughput.json`; the ≥1.5× fleet hit ratio and the x1
/// stalled-below-serial bound are pinned analytically by unit tests.
fn run_fabric() -> anyhow::Result<()> {
    let (hits_on, affine_on, wall_on, tps_on) = run_fabric_once(true)?;
    let (hits_off, affine_off, wall_off, tps_off) = run_fabric_once(false)?;
    println!(
        "affinity on : {hits_on} prefix block hits, {affine_on} affine routes, \
         {tps_on:>6.1} tok/s in {wall_on:.2}s"
    );
    println!(
        "affinity off: {hits_off} prefix block hits, {affine_off} affine routes, \
         {tps_off:>6.1} tok/s in {wall_off:.2}s"
    );
    let (t_on, stall_on, hidden_on) = run_fabric_overlap(true)?;
    let (t_off, stall_off, _) = run_fabric_overlap(false)?;
    println!(
        "overlap on  : {:.2}ms transfer, {:.2}ms stalled ({:.2}ms hidden)",
        t_on * 1e3,
        stall_on * 1e3,
        hidden_on * 1e3
    );
    println!(
        "overlap off : {:.2}ms transfer, {:.2}ms stalled (serial charge)",
        t_off * 1e3,
        stall_off * 1e3
    );
    let row = format!(
        "{{\n    \"workload\": \"2-card 170HX fleet, 3 identical-prompt families x \
         {REQUESTS} serial requests; swap-pressure arm for overlap\",\n    \
         \"affinity_on_prefix_hits\": {hits_on},\n    \
         \"affinity_off_prefix_hits\": {hits_off},\n    \
         \"fleet_hit_ratio\": {:.4},\n    \
         \"affine_routes\": {affine_on},\n    \
         \"affinity_on_tok_per_s\": {tps_on:.1},\n    \
         \"affinity_off_tok_per_s\": {tps_off:.1},\n    \
         \"overlap_on_stalled_ms\": {:.4},\n    \
         \"overlap_off_stalled_ms\": {:.4},\n    \
         \"swap_transfer_ms\": {:.4}\n  }}",
        hits_on as f64 / hits_off.max(1) as f64,
        stall_on * 1e3,
        stall_off * 1e3,
        t_on * 1e3,
    );
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_fabric", &row);
    Ok(())
}

/// One radix-cache arm: six returning users on a 2-card 170HX fleet, each
/// submitting the same personal prompt (shared system prefix + private
/// tail) for a second turn after their first retired. With retention on,
/// the released first-turn pages sit in the radix tree as reclaimable
/// cache and the second turn resurrects them; the `--no-kv-cache`
/// ablation freed them at refcount zero and re-prefills. Returns (fleet
/// prefix block hits, resurrected blocks, saved prefill s, resurrected
/// share of it, served tok/s).
fn run_radix_once(retention: bool) -> anyhow::Result<(u64, u64, f64, f64, f64)> {
    const USERS: usize = 6;
    let mut cfg = config(2, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::RoundRobin;
    cfg.qos.steal = false; // isolate caching from work movement
    cfg.batch.kv_retention = retention;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    let mut tokens = 0u64;
    for _turn in 0..2 {
        for user in 0..USERS {
            let mut prompt: Vec<i32> = (1..=6).map(|t| t * 7).collect();
            prompt.push(900 + user as i32);
            prompt.push(950 + user as i32);
            let resp = server.submit(prompt, TOKENS)?.recv()?;
            anyhow::ensure!(resp.ok(), "radix request failed: {:?}", resp.error);
            tokens += resp.tokens.len() as u64;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown_fleet().total();
    Ok((
        m.prefix_hits,
        m.resurrected_blocks,
        m.saved_prefill_s,
        m.saved_prefill_resurrected_s,
        tokens as f64 / wall,
    ))
}

/// The radix-cache ablation as a bench row: KV retention beyond refcount
/// zero vs the `--no-kv-cache` frees-at-zero baseline, on a returning-user
/// fleet workload. Recorded as the `serve_radix_cache` row of
/// `BENCH_sim_throughput.json`; the ≥1.5× fleet hit ratio is pinned
/// analytically by the returning-user acceptance unit test.
fn run_radix_cache() -> anyhow::Result<()> {
    let (hits_on, res_on, saved_on, saved_res_on, tps_on) = run_radix_once(true)?;
    let (hits_off, res_off, saved_off, _, tps_off) = run_radix_once(false)?;
    println!(
        "retention on : {hits_on} prefix block hits ({res_on} resurrected), \
         {:.2}ms prefill saved ({:.2}ms from cache), {tps_on:>6.1} tok/s",
        saved_on * 1e3,
        saved_res_on * 1e3,
    );
    println!(
        "retention off: {hits_off} prefix block hits ({res_off} resurrected), \
         {:.2}ms prefill saved, {tps_off:>6.1} tok/s",
        saved_off * 1e3,
    );
    let row = format!(
        "{{\n    \"workload\": \"2-card 170HX fleet, 6 returning users x 2 turns, \
         retention vs --no-kv-cache\",\n    \
         \"retention_on_prefix_hits\": {hits_on},\n    \
         \"retention_off_prefix_hits\": {hits_off},\n    \
         \"fleet_hit_ratio\": {:.4},\n    \
         \"resurrected_blocks\": {res_on},\n    \
         \"saved_prefill_on_ms\": {:.4},\n    \
         \"saved_prefill_resurrected_ms\": {:.4},\n    \
         \"saved_prefill_off_ms\": {:.4},\n    \
         \"retention_on_tok_per_s\": {tps_on:.1},\n    \
         \"retention_off_tok_per_s\": {tps_off:.1}\n  }}",
        hits_on as f64 / hits_off.max(1) as f64,
        saved_on * 1e3,
        saved_res_on * 1e3,
        saved_off * 1e3,
    );
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_radix_cache", &row);
    Ok(())
}

/// One chaos arm: a scripted node-0 death at engine round 3 on a 2-card
/// 170HX fleet, with sequence rescue on or off. Returns (ok responses,
/// wall seconds, rescued, lost).
fn run_chaos_once(rescue: bool) -> anyhow::Result<(usize, f64, u64, u64)> {
    use cmphx::faults::{FaultEvent, FaultKind, FaultPlan};
    let mut cfg = config(4, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::RoundRobin;
    cfg.qos.steal = false;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
    ];
    cfg.recovery.rescue = rescue;
    cfg.faults = Some(FaultPlan::script(vec![FaultEvent {
        node: 0,
        round: 3,
        kind: FaultKind::NodeDeath,
    }]));
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, 12).unwrap()
        })
        .collect();
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv()?.ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let fm = server.shutdown_fleet();
    let total = fm.total();
    Ok((ok, wall, total.rescued_seqs, total.lost_seqs))
}

/// The robustness ablation the chaos suite asserts on, as a bench row:
/// kill one of two cards mid-decode and compare goodput with sequence
/// rescue on vs the no-rescue arm. Recorded as the `serve_chaos` row of
/// `BENCH_sim_throughput.json`.
fn run_chaos() -> anyhow::Result<()> {
    let (ok_on, wall_on, rescued_on, lost_on) = run_chaos_once(true)?;
    let (ok_off, wall_off, rescued_off, lost_off) = run_chaos_once(false)?;
    println!(
        "rescue on : {ok_on}/{REQUESTS} served in {wall_on:.2}s | rescued={rescued_on} lost={lost_on}"
    );
    println!(
        "rescue off: {ok_off}/{REQUESTS} served in {wall_off:.2}s | rescued={rescued_off} lost={lost_off}"
    );
    let row = format!(
        "{{\n    \"workload\": \"2-card 170HX fleet, scripted node-0 death at engine round 3, \
         {REQUESTS} requests x 12 tokens\",\n    \
         \"rescue_on_goodput\": {:.4},\n    \
         \"rescue_on_rescued\": {rescued_on},\n    \
         \"rescue_on_lost\": {lost_on},\n    \
         \"rescue_on_wall_s\": {wall_on:.3},\n    \
         \"rescue_off_goodput\": {:.4},\n    \
         \"rescue_off_lost\": {lost_off},\n    \
         \"rescue_off_wall_s\": {wall_off:.3}\n  }}",
        ok_on as f64 / REQUESTS as f64,
        ok_off as f64 / REQUESTS as f64,
    );
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_chaos", &row);
    Ok(())
}

/// One trace-overhead arm: the standard workload with the span tracer on
/// or off. Returns (tokens, simulated device s, wall s, journal bytes,
/// retained spans).
fn run_trace_once(trace: bool) -> anyhow::Result<(u64, f64, f64, usize, usize)> {
    let mut cfg = config(4, StepPolicy::RoundRobin);
    cfg.trace = trace;
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    submit_workload(&server, REQUESTS)?;
    let wall = t0.elapsed().as_secs_f64();
    let tracer = server.tracer();
    let m = server.shutdown();
    let snap = tracer.snapshot();
    let journal = cmphx::obsv::journal_jsonl(&snap);
    Ok((m.tokens_out, m.simulated_device_s, wall, journal.len(), snap.events.len()))
}

/// The tracing ablation: the same workload with the span tracer on vs
/// off. The overhead bound is analytic, not statistical: every trace
/// stamp reads the *simulated* clock, so the simulated goodput of the
/// tracing-on arm must equal the tracing-off arm exactly — asserted here
/// bit-for-bit — and only wall time may move. Recorded as the
/// `serve_trace_overhead` row of `BENCH_sim_throughput.json`.
fn run_trace_overhead() -> anyhow::Result<()> {
    let (tok_on, sim_on, wall_on, journal_bytes, spans) = run_trace_once(true)?;
    let (tok_off, sim_off, wall_off, off_bytes, off_spans) = run_trace_once(false)?;
    anyhow::ensure!(
        tok_on == tok_off && sim_on == sim_off,
        "tracing moved the simulated numbers: {tok_on}/{sim_on} vs {tok_off}/{sim_off}"
    );
    anyhow::ensure!(spans > 0 && journal_bytes > 0, "tracing-on arm produced no journal");
    anyhow::ensure!(off_spans == 0, "disabled tracer retained {off_spans} spans");
    let _ = off_bytes; // header-only journal on the off arm
    println!(
        "trace on : {tok_on} tok, sim {:.2}ms, wall {wall_on:.2}s | {spans} spans, \
         {journal_bytes} journal bytes",
        sim_on * 1e3
    );
    println!(
        "trace off: {tok_off} tok, sim {:.2}ms, wall {wall_off:.2}s | sim goodput identical \
         (analytic bound)",
        sim_off * 1e3
    );
    let row = format!(
        "{{\n    \"workload\": \"single 170HX, {REQUESTS} requests x {TOKENS} tokens, span \
         tracer on vs off\",\n    \
         \"trace_on_tokens\": {tok_on},\n    \
         \"trace_on_sim_ms\": {:.4},\n    \
         \"trace_off_sim_ms\": {:.4},\n    \
         \"sim_goodput_identical\": true,\n    \
         \"trace_on_wall_s\": {wall_on:.3},\n    \
         \"trace_off_wall_s\": {wall_off:.3},\n    \
         \"spans\": {spans},\n    \
         \"journal_bytes\": {journal_bytes}\n  }}",
        sim_on * 1e3,
        sim_off * 1e3,
    );
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_trace_overhead", &row);
    Ok(())
}

/// Format one knee curve as a JSON array of
/// `[rho, goodput_tok_s, p99_ms, p999_ms, attainment, tok_per_joule]`
/// points, where rho is offered load over fleet capacity.
fn fmt_curve(points: &[cmphx::load::CurvePoint], cap_rps: f64) -> String {
    let cells: Vec<String> = points
        .iter()
        .map(|p| {
            let r = &p.report;
            format!(
                "[{:.3}, {:.1}, {:.1}, {:.1}, {:.4}, {:.3}]",
                p.offered_rps / cap_rps,
                r.goodput_tps,
                r.p99_s * 1e3,
                r.p999_s * 1e3,
                r.slo_attainment().unwrap_or(1.0),
                r.goodput_tokens_per_joule,
            )
        })
        .collect();
    format!("[{}]", cells.join(", "))
}

/// The open-loop overload harness: sweep offered load through the latency
/// knee on the pure discrete-event fleet model ([`cmphx::load::sim`]) —
/// no artifacts or PJRT involved, so this arm runs everywhere. Four arms
/// per load point: admission control on vs the `--no-admission-control`
/// ablation, each calm and under seeded chaos. Records offered load vs
/// goodput / p99 / p99.9 / SLO attainment / tokens-per-joule as the
/// `serve_openloop` row of `BENCH_sim_throughput.json`; the past-the-knee
/// AC win and the below-knee bit-identity are pinned by
/// `tests/integration_load.rs`.
fn run_openloop() -> anyhow::Result<()> {
    use cmphx::faults::FaultPlan;
    use cmphx::load::{
        capacity_rps, sweep, ArrivalPlan, ArrivalProcess, NodeModel, SimConfig, WorkloadShape,
    };

    const SEED: u64 = 0x0417_C0DE;
    let shape = WorkloadShape {
        tenants: 3,
        prompt_len: 32,
        shared_prefix_len: 16,
        families: 4,
        max_tokens: 8,
    };
    let plan =
        ArrivalPlan::seeded(ArrivalProcess::Poisson { rps: 40.0 }, SEED, 30.0, &shape);
    let cfg = SimConfig::uniform(2, NodeModel::cmp170hx_like(), shape.tenants, Some(0.5));
    let cap = capacity_rps(&plan, &cfg);
    anyhow::ensure!(cap > 0.0, "degenerate plan: zero fleet capacity");
    // Normalize the ladder to capacity so the x axis is rho (offered /
    // capacity) regardless of the base plan's rate.
    let base = cap / plan.offered_rps();
    let rho = [0.5, 0.8, 1.0, 1.2, 1.5, 2.0];
    let mults: Vec<f64> = rho.iter().map(|m| m * base).collect();
    let chaos = SimConfig {
        chaos: Some(FaultPlan::seeded(SEED ^ 0xFA17, cfg.nodes.len(), 64, 0.05)),
        ..cfg.clone()
    };

    let arms = [
        ("ac", cfg.clone()),
        ("no_ac", cfg.without_admission()),
        ("ac_chaos", chaos.clone()),
        ("no_ac_chaos", chaos.without_admission()),
    ];
    let mut curves = Vec::new();
    for (name, arm) in &arms {
        let points = sweep(&plan, &mults, arm);
        for p in &points {
            let r = &p.report;
            println!(
                "{name:<11} rho={:>4.2} offered={:>6.1}rps | goodput {:>7.1} tok/s \
                 p99 {:>7.1}ms p99.9 {:>7.1}ms | attain {:>5.1}% {:>6.3} tok/J | \
                 shed={} miss={} late={}",
                p.offered_rps / cap,
                p.offered_rps,
                r.goodput_tps,
                r.p99_s * 1e3,
                r.p999_s * 1e3,
                r.slo_attainment().unwrap_or(1.0) * 100.0,
                r.goodput_tokens_per_joule,
                r.shed_admission,
                r.deadline_misses,
                r.served_late,
            );
        }
        curves.push((*name, points));
    }
    // Same seed, same curves — the reproducibility contract, including
    // under chaos (both the arrival plan and the fault plan are seeded).
    let replay = sweep(&plan, &mults, &arms[2].1);
    anyhow::ensure!(replay == curves[2].1, "chaos sweep must replay bit-identically");
    // Past the knee the AC arm must beat the ablation on both goodput and
    // attainment — the congestion-collapse headline this row exists for.
    let (ac_top, bare_top) =
        (&curves[0].1.last().unwrap().report, &curves[1].1.last().unwrap().report);
    anyhow::ensure!(
        ac_top.goodput_tokens > bare_top.goodput_tokens
            && ac_top.slo_attainment() > bare_top.slo_attainment(),
        "admission control must win past the knee: {ac_top:?} vs {bare_top:?}"
    );

    let arm_rows: Vec<String> = curves
        .iter()
        .map(|(name, points)| format!("\"{name}\": {}", fmt_curve(points, cap)))
        .collect();
    let row = format!(
        "{{\n    \"workload\": \"2-model-card open-loop Poisson sweep, 3 tenants with a \
         500 ms SLO, seed {SEED:#x}, rho 0.5..2.0; point = [rho, goodput_tok_s, p99_ms, \
         p999_ms, attainment, tok_per_joule]\",\n    \
         \"capacity_rps\": {cap:.2},\n    {}\n  }}",
        arm_rows.join(",\n    "),
    );
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_sim_throughput.json");
    upsert_bench_row(&path, "serve_openloop", &row);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== open-loop overload: offered load through the knee (pure fleet model) ==");
    run_openloop()?;
    if !cmphx::runtime::pjrt_available() {
        println!("e2e serving bench skipped: PJRT unavailable (stub xla build)");
        return Ok(());
    }
    if artifacts().is_err() {
        println!("e2e serving bench skipped: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    println!("== e2e serving: {REQUESTS} requests × {TOKENS} tokens (tiny-qwen over PJRT) ==");
    for max_batch in [1, 2, 4, 8] {
        run_once(max_batch, StepPolicy::RoundRobin)?;
    }
    println!("-- scheduler ablation at batch=4 --");
    run_once(4, StepPolicy::ShortestFirst)?;
    println!("-- prefix sharing: identical-prompt burst, cache on vs off --");
    run_prefix_ablation(true)?;
    run_prefix_ablation(false)?;
    println!("-- paged KV under page pressure: preempt-and-requeue ablation --");
    run_pressure(true, false)?;
    run_pressure(true, true)?;
    run_pressure(false, false)?;
    println!("-- fleet: 170HX + 90HX, continuous batching, weighted routing --");
    run_fleet()?;
    println!("-- fairness: flooding tenant, WFQ + work stealing on vs off --");
    run_fairness()?;
    println!("-- chaos: scripted card death mid-decode, rescue on vs off --");
    run_chaos()?;
    println!("-- KV fabric: prefix-affine routing + swap-decode overlap ablations --");
    run_fabric()?;
    println!("-- radix cache: returning users, KV retention vs --no-kv-cache --");
    run_radix_cache()?;
    println!("-- observability: span tracer on vs off (simulated goodput must not move) --");
    run_trace_overhead()?;
    Ok(())
}
