//! CLI command dispatch.

use anyhow::{bail, Result};

use crate::bench_harness::Table;
use crate::calibration as cal;
use crate::coordinator::{Server, ServerConfig, ServerHandle};
use crate::device::registry;
use crate::report::{figures, specs};
use crate::runtime::ArtifactDir;

use super::args::Args;

const HELP: &str = "\
cmphx — CMP 170HX reuse-study platform (paper reproduction)

USAGE: cmphx <command> [args]

COMMANDS:
  specs [name]              device spec sheets (Tables 2-1…2-5)
  bench <suite>             fp32|fp16|fp64|int32|int8|membw|pcie|all (Graphs 3-x, EX)
  llama-bench               the §4 grid: prefill/decode/efficiency (Graphs 4-1…4-3)
  market                    sales + reuse economics (Tables 1-1/1-2)
  report [--csv]            regenerate every figure with paper deviations
  targets                   check simulator output against calibration targets
  sweep [precision] [--device d]
                            mixbench operational-intensity sweep (roofline)
  serve [--requests N] [--tokens N] [--batch N] [--fleet a,b,…]
        [--block N] [--kv-blocks N] [--no-preempt]
        [--no-prefix-cache] [--no-kv-cache] [--swap] [--host-pool MiB]
        [--tenant name:weight[:tok_s][:joules][:slo_ms]]… [--no-qos]
        [--no-steal] [--no-affinity] [--affinity-bonus F] [--admit-scan K]
        [--no-overlap] [--aging N] [--aging-rounds N]
        [--reclaim-policy lru|depth] [--no-admission-control]
        [--chaos-seed N] [--chaos-rate F] [--no-rescue] [--retries N]
        [--deadline-ms N] [--probation N] [--trace FILE]
                            end-to-end: serve the AOT tiny-qwen via PJRT,
                            optionally across a fleet of registry cards
                            (e.g. --fleet 170hx,90hx) with continuous
                            batching over paged KV (--block positions per
                            page, --kv-blocks caps the page pool to force
                            pressure) and preempt-and-requeue under page
                            pressure (--no-preempt stalls instead).
                            Prompt blocks are prefix-shared copy-on-write
                            (--no-prefix-cache for the ablation), and
                            released blocks stay cached in each card's
                            radix tree for returning users until page
                            pressure reclaims them (--no-kv-cache frees at
                            refcount zero instead; --reclaim-policy picks
                            the cached-tier victim — lru, or depth to
                            spend deep private tails before shallow
                            shared system prefixes); --swap
                            arms swap-based preemption — victims whose KV
                            round-trips the card's PCIe link cheaper than
                            it recomputes park in a host-RAM pool of
                            --host-pool MiB (default 1024) instead of
                            replaying. --tenant (repeatable) registers QoS
                            tenants: weighted fair queueing with optional
                            token-rate and energy-budget caps plus an
                            slo_ms latency contract (stamped as each
                            request's deadline, scored in the per-tenant
                            attainment rollup, and enforced at submit by
                            adaptive admission control — doomed requests
                            shed before any prefill, escalating down a
                            brownout ladder under sustained overload;
                            --no-admission-control is the reactive-only
                            ablation); requests round-robin across them. --no-qos falls back
                            to the FIFO queue, --no-steal disables
                            cross-node work stealing (queued requests and
                            parked-sequence migration), --no-affinity
                            disables prefix-affine routing (dispatch falls
                            back to the plain fleet policy),
                            --affinity-bonus sets its peak multiplier
                            (must be > 1.0; default 2.0), --admit-scan
                            bounds the capacity-edge queue scan that
                            prefers radix-resident prompts (default 4,
                            1 = head-only), --no-overlap
                            charges swap DMA serially instead of hiding it
                            under the decode round, --aging sets the WFQ
                            promoter (pops), --aging-rounds the preemption
                            park-lot gate. --chaos-seed arms the
                            seeded fault injector (card death, stalls,
                            link downgrades, VRAM page loss, swap-in
                            failures, thermal throttles) at --chaos-rate
                            faults/node/round (default 0.05); the engine
                            self-heals — rescued sequences replay
                            bit-identically on surviving cards. --retries
                            bounds transient-failure retries,
                            --deadline-ms stamps a wall-clock SLO on each
                            request, --probation sets the probe serves a
                            recovered card must pass, --no-rescue is the
                            ablation arm that drops a dead card's work.
                            --trace FILE arms per-request span tracing
                            (simulated-clock stamps, bounded flight
                            recorders, per-round fleet time-series) and
                            writes the JSONL journal to FILE plus a
                            Perfetto-loadable Chrome trace to
                            FILE.chrome.json, with a latency-attribution
                            rollup printed after the fleet report
  trace <journal> [--chrome FILE]
                            re-render a --trace journal: parse + validate
                            every line, list flight dumps, print the
                            latency-attribution rollup; --chrome re-emits
                            the Chrome trace view
  help                      this text
";

/// Run a parsed command; returns the process exit code.
pub fn run(args: &Args) -> Result<i32> {
    match args.command.as_str() {
        "help" | "--help" => {
            print!("{HELP}");
            Ok(0)
        }
        "specs" => {
            match args.pos(0) {
                Some(name) => match registry::by_name(name) {
                    Some(dev) => print!("{}", specs::spec_sheet(&dev)),
                    None => bail!("unknown device {name:?}"),
                },
                None => print!("{}", specs::all_spec_sheets()),
            }
            Ok(0)
        }
        "bench" => {
            let suite = args.pos(0).unwrap_or("all");
            for t in bench_tables(suite)? {
                emit(&t, args);
            }
            Ok(0)
        }
        "llama-bench" => {
            for t in [figures::graph_4_1(), figures::graph_4_2(), figures::graph_4_3()] {
                emit(&t, args);
            }
            Ok(0)
        }
        "market" => {
            emit(&figures::table_1_1(), args);
            emit(&figures::table_1_2(), args);
            print_reuse();
            Ok(0)
        }
        "report" => {
            for t in figures::all_figures() {
                emit(&t, args);
            }
            Ok(0)
        }
        "targets" => {
            let failed = check_targets();
            Ok(if failed == 0 { 0 } else { 1 })
        }
        "sweep" => {
            // mixbench's native output: the operational-intensity sweep
            // that traces the roofline (the source data behind Graphs 3-x).
            use crate::bench::{mixbench, Precision};
            use crate::isa::pass::FmadPolicy;
            let precision = match args.pos(0).unwrap_or("fp32") {
                "fp32" => Precision::Fp32,
                "fp16" => Precision::Fp16Half2,
                "fp64" => Precision::Fp64,
                "int32" => Precision::Int32,
                "int8" => Precision::Int8,
                other => bail!("unknown precision {other:?}"),
            };
            let dev = match args.opt("device") {
                Some(name) => registry::by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown device {name:?}"))?,
                None => registry::cmp170hx(),
            };
            for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
                println!(
                    "\n== mixbench {} sweep on {} ({}) ==",
                    precision.name(),
                    dev.name,
                    policy.name()
                );
                println!(
                    "{:>6} {:>12} {:>12} {:>12} {:>10}",
                    "iters", "flops/byte", "ex.time ms", "G(FL)OPS", "GB/s"
                );
                for r in mixbench::sweep(&dev, precision, policy) {
                    let iters: u64 = r
                        .case
                        .split("c=")
                        .nth(1)
                        .and_then(|s| s.split_whitespace().next())
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0);
                    let gops = if precision.integer() {
                        r.tiops() * 1e3
                    } else {
                        r.tflops() * 1e3
                    };
                    println!(
                        "{:>6} {:>12.3} {:>12.4} {:>12.1} {:>10.1}",
                        iters,
                        mixbench::flops_per_byte(precision, iters),
                        r.timing.time_s * 1e3,
                        gops,
                        r.gbps()
                    );
                }
            }
            Ok(0)
        }
        "serve" => serve(args),
        "trace" => trace_cmd(args),
        other => bail!("unknown command {other:?}; try `cmphx help`"),
    }
}

fn bench_tables(suite: &str) -> Result<Vec<Table>> {
    Ok(match suite {
        "fp32" => vec![figures::graph_3_1()],
        "fp16" => vec![figures::graph_3_2()],
        "fp64" => vec![figures::graph_3_3()],
        "int32" => vec![figures::graph_3_4()],
        "int8" => vec![figures::graph_ex1()],
        "membw" => vec![figures::graph_3_5()],
        "pcie" => vec![figures::graph_ex2()],
        "all" => vec![
            figures::graph_3_1(),
            figures::graph_3_2(),
            figures::graph_3_3(),
            figures::graph_3_4(),
            figures::graph_3_5(),
            figures::graph_ex1(),
            figures::graph_ex2(),
        ],
        other => bail!("unknown suite {other:?}"),
    })
}

fn emit(t: &Table, args: &Args) {
    if args.flag("csv") {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
}

fn print_reuse() {
    use crate::isa::pass::FmadPolicy;
    use crate::llm::quant;
    use crate::market::tco;
    println!("\n== Reuse value (Q4_K_M decode, duty 100%) ==");
    for (dev, policy) in [
        (registry::cmp170hx(), FmadPolicy::Fused),
        (registry::cmp170hx(), FmadPolicy::Decomposed),
        (registry::a100_pcie(), FmadPolicy::Fused),
    ] {
        let v = tco::reuse_value(&dev, &quant::Q4_K_M, policy, 1.0);
        println!(
            "{:<22} {:>9}  ${:>7.0}/TFLOP(fp32)  ${:>6.2}/(tok/s)  energy ${:>5.0}/yr  {:.0} tok/s",
            v.device,
            policy.name(),
            v.usd_per_tflop_fp32,
            v.usd_per_decode_tps,
            v.energy_usd_per_year,
            v.decode_tps,
        );
    }
}

fn check_targets() -> usize {
    use crate::bench::{membench, mixbench, openclbench, Precision};
    use crate::isa::ir::MemPattern;
    use crate::isa::pass::FmadPolicy;
    let dev = registry::cmp170hx();
    let measured: Vec<(&cal::Target, f64)> = vec![
        (
            &cal::FP32_DEFAULT_TFLOPS,
            openclbench::peak(&dev, Precision::Fp32, FmadPolicy::Fused).tflops(),
        ),
        (
            &cal::FP32_NOFMA_TFLOPS,
            openclbench::peak(&dev, Precision::Fp32, FmadPolicy::Decomposed).tflops(),
        ),
        (&cal::FP32_THEORETICAL_TFLOPS, dev.fp32_tflops()),
        (
            &cal::FP16_HALF2_TFLOPS,
            openclbench::peak(&dev, Precision::Fp16Half2, FmadPolicy::Fused).tflops(),
        ),
        (&cal::FP16_THEORETICAL_TFLOPS, dev.fp16_tflops()),
        (
            &cal::FP64_DEFAULT_TFLOPS,
            openclbench::peak(&dev, Precision::Fp64, FmadPolicy::Fused).tflops(),
        ),
        (
            &cal::FP64_NOFMA_TFLOPS,
            openclbench::peak(&dev, Precision::Fp64, FmadPolicy::Decomposed).tflops(),
        ),
        (&cal::FP64_THEORETICAL_TFLOPS, dev.fp64_tflops()),
        (
            &cal::INT32_OPENCL_TIOPS,
            openclbench::peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops(),
        ),
        (
            &cal::INT32_CUDA_TIOPS,
            mixbench::peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops(),
        ),
        (
            &cal::MEMBW_COALESCED_GBPS,
            membench::run(&dev, membench::Dir::Read, MemPattern::Coalesced).gbps(),
        ),
        (&cal::MEMBW_THEORETICAL_GBPS, dev.mem.peak_bw / 1e9),
        (
            &cal::INT8_OPENCL_TIOPS,
            openclbench::peak(&dev, Precision::Int8, FmadPolicy::Fused).tiops(),
        ),
        (
            &cal::INT8_CUDA_TIOPS,
            mixbench::peak(&dev, Precision::Int8, FmadPolicy::Fused).tiops(),
        ),
        (&cal::PCIE_STOCK_THEORETICAL_GBPS, dev.pcie.theoretical_bw() / 1e9),
    ];
    let mut failed = 0;
    println!("{:<22} {:>10} {:>10} {:>7}  figure", "target", "paper", "ours", "ok");
    for (t, m) in measured {
        let ok = cal::check(t, m);
        if !ok {
            failed += 1;
        }
        println!(
            "{:<22} {:>10.4} {:>10.4} {:>7}  {}",
            t.id,
            t.value,
            m,
            if ok { "✓" } else { "✗" },
            t.figure
        );
    }
    println!("{failed} target(s) failed");
    failed
}

fn serve(args: &Args) -> Result<i32> {
    use crate::coordinator::NodeConfig;
    use crate::qos::TenantSpec;

    let requests = args.opt_usize("requests", 8)?;
    let tokens = args.opt_usize("tokens", 12)?;
    let batch = args.opt_usize("batch", 4)?;

    let artifacts = ArtifactDir::discover()?;
    let mut config = ServerConfig::default();
    config.batch.max_batch = batch;
    config.batch.kv_block_positions =
        args.opt_usize("block", config.batch.kv_block_positions)?;
    if let Some(cap) = args.opt("kv-blocks") {
        config.batch.kv_block_budget = Some(cap.parse()?);
    }
    if args.flag("no-preempt") {
        config.batch.preempt = false;
    }
    if args.flag("no-prefix-cache") {
        config.batch.prefix_cache = false;
    }
    if args.flag("no-kv-cache") {
        config.batch.kv_retention = false;
    }
    config.batch.reclaim = match args.opt("reclaim-policy") {
        None | Some("lru") => crate::coordinator::ReclaimPolicy::Lru,
        Some("depth") => crate::coordinator::ReclaimPolicy::Depth,
        Some(other) => bail!("--reclaim-policy must be lru or depth, got {other:?}"),
    };
    if args.flag("swap") {
        config.batch.swap = true;
    }
    config.batch.host_pool_bytes =
        (args.opt_usize("host-pool", (config.batch.host_pool_bytes >> 20) as usize)? as u64) << 20;
    config.batch.aging_rounds =
        args.opt_usize("aging-rounds", config.batch.aging_rounds as usize)? as u64;
    for spec in args.opt_all("tenant") {
        config.qos.tenants.push(TenantSpec::parse(spec)?);
    }
    if args.flag("no-qos") {
        config.qos.enabled = false;
    }
    if args.flag("no-steal") {
        config.qos.steal = false;
    }
    if args.flag("no-affinity") {
        config.affinity = false;
    }
    if args.flag("no-overlap") {
        config.overlap = false;
    }
    if args.flag("no-admission-control") {
        config.admission = false;
    }
    config.qos.aging_pops = args.opt_usize("aging", config.qos.aging_pops as usize)? as u64;
    config.qos.admit_scan = args.opt_usize("admit-scan", config.qos.admit_scan)?;
    config.qos.affinity_bonus =
        args.opt_f64("affinity-bonus", config.qos.affinity_bonus)?;
    // NaN fails this too; values <= 1.0 would silently degrade affine
    // routing to the plain policy — that ablation is spelled --no-affinity.
    if !(config.qos.affinity_bonus > 1.0) {
        bail!(
            "--affinity-bonus must be > 1.0 (got {}); use --no-affinity for the ablation",
            config.qos.affinity_bonus
        );
    }
    if let Some(list) = args.opt("fleet") {
        let fmad = config.fmad;
        // Reject empty segments explicitly: by_name does substring
        // matching, so "" would silently resolve to the first registry
        // entry instead of erroring.
        config.nodes = list
            .split(',')
            .map(str::trim)
            .map(|name| {
                if name.is_empty() {
                    bail!("empty device name in --fleet list {list:?}");
                }
                registry::by_name(name)
                    .map(|dev| NodeConfig::new(dev, fmad))
                    .ok_or_else(|| anyhow::anyhow!("unknown fleet device {name:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        if config.nodes.is_empty() {
            bail!("--fleet list is empty");
        }
    }
    // Self-healing knobs and the seeded chaos injector.
    if args.flag("no-rescue") {
        config.recovery.rescue = false;
    }
    config.recovery.max_retries =
        args.opt_usize("retries", config.recovery.max_retries as usize)? as u32;
    config.recovery.probation_rounds =
        args.opt_usize("probation", config.recovery.probation_rounds as usize)? as u64;
    if let Some(ms) = args.opt("deadline-ms") {
        config.recovery.deadline =
            Some(std::time::Duration::from_millis(ms.parse()?));
    }
    if let Some(seed) = args.opt("chaos-seed") {
        use crate::faults::FaultPlan;
        let rate: f64 = args.opt("chaos-rate").unwrap_or("0.05").parse()?;
        if !(0.0..=1.0).contains(&rate) {
            bail!("--chaos-rate must be in [0, 1], got {rate}");
        }
        let fleet_size = config.nodes.len().max(1);
        config.faults = Some(FaultPlan::seeded(seed.parse()?, fleet_size, 64, rate));
    } else if args.opt("chaos-rate").is_some() {
        bail!("--chaos-rate needs --chaos-seed (the injector is seed-driven)");
    }
    // --trace FILE arms the span tracer; the journal + Chrome view are
    // written after the fleet report, from the tracer's final snapshot.
    let trace_path = args.opt("trace").map(str::to_string);
    config.trace = trace_path.is_some();
    println!("compiling artifacts on the PJRT CPU client…");
    let server: ServerHandle = Server::start(artifacts, config)?;

    // Registered tenants take turns submitting; without --tenant,
    // everything bills to the implicit default tenant.
    let lanes: Vec<_> = {
        use crate::qos::TenantRegistry;
        let named: Vec<_> = server
            .registry()
            .iter()
            .map(|(t, _)| t)
            .filter(|&t| t != TenantRegistry::DEFAULT)
            .collect();
        if named.is_empty() { vec![TenantRegistry::DEFAULT] } else { named }
    };
    let mut rxs = Vec::new();
    for i in 0..requests {
        let prompt: Vec<i32> = (1..=8).map(|t| ((t * (i as i32 + 3)) % 500) + 1).collect();
        rxs.push(server.submit_as(lanes[i % lanes.len()], prompt, tokens)?);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()?;
        let preempted = if resp.preemptions > 0 {
            format!(" preempted×{} (swapped×{})", resp.preemptions, resp.swaps)
        } else {
            String::new()
        };
        let rescued = if resp.rescues > 0 {
            format!(" rescued×{}", resp.rescues)
        } else {
            String::new()
        };
        println!(
            "req {i} [{}]: {} tokens on node {}, latency {:.1} ms (sim device {:.2} ms){}{}{}",
            server.registry().spec(resp.tenant).name,
            resp.tokens.len(),
            resp.node,
            resp.latency_s() * 1e3,
            resp.simulated_device_s * 1e3,
            preempted,
            rescued,
            resp.error.as_deref().map(|e| format!(" ERROR {e}")).unwrap_or_default(),
        );
    }
    let tracer = server.tracer();
    let fleet = server.shutdown_fleet();
    println!("\n{}", fleet.render());
    if let Some(path) = trace_path {
        use crate::obsv::{attribution_rollup, chrome_trace, journal_jsonl};
        let snap = tracer.snapshot();
        std::fs::write(&path, journal_jsonl(&snap))?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, chrome_trace(&snap))?;
        println!(
            "trace: {} span(s), {} flight dump(s), {} series point(s) → {path} \
             (chrome: {chrome})",
            snap.events.len(),
            snap.dumps.len(),
            snap.series.len()
        );
        print!("{}", attribution_rollup(&snap));
    }
    Ok(0)
}

/// `cmphx trace <journal>`: parse a `--trace` journal back, list its
/// flight dumps, and print the latency-attribution rollup — the offline
/// reader for journals produced by `serve --trace`.
fn trace_cmd(args: &Args) -> Result<i32> {
    use crate::obsv::{attribution_rollup, chrome_trace, parse_journal};
    let Some(path) = args.pos(0) else {
        bail!("usage: cmphx trace <journal.jsonl> [--chrome FILE]");
    };
    let text = std::fs::read_to_string(path)?;
    let snap = parse_journal(&text)?;
    println!(
        "{}: {} span(s), {} flight dump(s), {} series point(s), {} dispatch tick(s)",
        path,
        snap.events.len(),
        snap.dumps.len(),
        snap.series.len(),
        snap.dispatch.len()
    );
    for d in &snap.dumps {
        println!(
            "flight dump: node {} round {} sim {:.4}s — {} ({} event(s), {} dropped)",
            d.node,
            d.round,
            d.sim_s,
            d.reason,
            d.events.len(),
            d.dropped
        );
    }
    print!("{}", attribution_rollup(&snap));
    if let Some(out) = args.opt("chrome") {
        std::fs::write(out, chrome_trace(&snap))?;
        println!("chrome trace → {out}");
    }
    Ok(0)
}
