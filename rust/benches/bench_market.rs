//! `cargo bench` target regenerating Tables 1-1 and 1-2 plus the §6.2
//! reuse-economics sweep.

use cmphx::bench_harness::time_fn;
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::quant;
use cmphx::market::tco;
use cmphx::report::figures;

fn main() {
    for table in [figures::table_1_1(), figures::table_1_2()] {
        print!("{}", table.render());
        if let Some(worst) = table.worst_deviation() {
            println!("worst deviation vs paper: {:+.2}%", worst * 100.0);
        }
    }

    println!("\n== reuse value sweep ($/(tok/s), q4_k_m decode) ==");
    for (dev, policy) in [
        (registry::cmp170hx(), FmadPolicy::Fused),
        (registry::cmp170hx(), FmadPolicy::Decomposed),
        (registry::cmp170hx_x16(), FmadPolicy::Decomposed),
        (registry::a100_pcie(), FmadPolicy::Fused),
    ] {
        let v = tco::reuse_value(&dev, &quant::Q4_K_M, policy, 1.0);
        println!(
            "{:<24} {:>9}  {:>8.2} $/(tok/s)  {:>7.0} tok/s",
            v.device,
            policy.name(),
            v.usd_per_decode_tps,
            v.decode_tps
        );
    }

    let stats = time_fn(1, 5, || {
        std::hint::black_box(figures::table_1_2());
    });
    println!(
        "\ntable generation: mean {:.3} ms (σ {:.3} ms)",
        stats.mean_s * 1e3,
        stats.stddev_s * 1e3
    );
}
