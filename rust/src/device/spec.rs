//! Full device specification — everything downstream models consume.

use super::rates::IssueRates;
use super::throttle::ThrottleProfile;
use crate::isa::class::InstClass;
use crate::memhier::hbm::MemorySystem;
use crate::memhier::pcie::PcieLink;
use crate::power::PowerModel;

/// A complete GPU model: silicon (SMs, clocks, issue rates), the limiter
/// profile, memory system, host link, and power model — plus the catalogue
/// metadata the market model uses.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Microarchitecture label (Table 2-1).
    pub arch: &'static str,
    pub sms: u32,
    pub cuda_cores: u32,
    pub base_clock_hz: f64,
    pub boost_clock_hz: f64,
    pub rates: IssueRates,
    pub throttle: ThrottleProfile,
    pub mem: MemorySystem,
    pub pcie: PcieLink,
    pub power: PowerModel,
    pub tdp_w: f64,
    /// L1/shared per SM, bytes (Table 2-2: 192 KB).
    pub l1_bytes_per_sm: u64,
    /// Street price in USD (Table 1-1 for CMP cards; public list/market
    /// price for references). Used by `market/`.
    pub price_usd: f64,
    /// Release label for reports.
    pub released: &'static str,
}

impl DeviceSpec {
    /// Theoretical peak for a class at boost clock, expressed in the
    /// quantity the paper's graphs use (TFLOPs for float classes, TIOPs for
    /// int), *ignoring the throttle* — "theoretical" always means the
    /// silicon's capability.
    pub fn theoretical_class_rate(&self, class: InstClass) -> f64 {
        let inst_per_s = self.sms as f64 * self.rates.class_rate(class) * self.boost_clock_hz;
        let ops = if class.flops() > 0 {
            class.flops() as f64
        } else {
            class.iops() as f64
        };
        inst_per_s * ops / 1e12
    }

    /// Effective issue rate (inst/s, whole device) for a class *after* the
    /// limiter, at boost clock.
    pub fn effective_issue_rate(&self, class: InstClass) -> f64 {
        self.sms as f64
            * self.rates.class_rate(class)
            * self.throttle.mult(class)
            * self.boost_clock_hz
    }

    /// Theoretical FP32 TFLOPS (headline spec, Table 2-4).
    pub fn fp32_tflops(&self) -> f64 {
        self.theoretical_class_rate(InstClass::Ffma)
    }

    /// Theoretical FP16 (packed, non-tensor) TFLOPS.
    pub fn fp16_tflops(&self) -> f64 {
        self.theoretical_class_rate(InstClass::Hfma2)
    }

    /// Theoretical FP64 TFLOPS.
    pub fn fp64_tflops(&self) -> f64 {
        self.theoretical_class_rate(InstClass::Dfma)
    }

    /// Tensor-core dense f16 TFLOPS (0 when dark).
    pub fn tensor_f16_tflops(&self) -> f64 {
        self.sms as f64
            * self.rates.tensor_f16_flops
            * self.throttle.mult(InstClass::HmmaF16)
            * self.boost_clock_hz
            / 1e12
    }

    /// Swap the throttle profile (used by the §5.4 pathway explorer).
    pub fn with_throttle(mut self, throttle: ThrottleProfile) -> Self {
        self.throttle = throttle;
        self
    }
}

#[cfg(test)]
mod tests {
    use crate::device::registry;
    use crate::isa::class::InstClass::*;
    use crate::testutil::assert_close;

    #[test]
    fn cmp170hx_theoretical_matches_table_2_4() {
        let d = registry::cmp170hx();
        assert_close(d.fp32_tflops(), 12.63, 0.01);
        assert_close(d.fp16_tflops(), 50.53, 0.01);
        assert_close(d.fp64_tflops(), 6.317, 0.01);
    }

    #[test]
    fn cmp170hx_effective_ffma_is_one_thirtysecond() {
        let d = registry::cmp170hx();
        let native = d.sms as f64 * d.rates.fp32 * d.boost_clock_hz;
        assert_close(d.effective_issue_rate(Ffma), native / 32.0, 1e-12);
        assert_close(d.effective_issue_rate(Fmul), native, 1e-12);
    }

    #[test]
    fn a100_is_uncrippled() {
        let d = registry::a100_pcie();
        assert!(!d.throttle.is_crippled());
        assert_close(d.fp32_tflops(), 19.5, 0.02);
        assert!(d.tensor_f16_tflops() > 200.0); // ~312 TFLOPS dense
    }

    #[test]
    fn cmp_tensor_cores_are_dark() {
        assert_eq!(registry::cmp170hx().tensor_f16_tflops(), 0.0);
    }

    #[test]
    fn theoretical_ignores_throttle() {
        // "theoretical" = silicon capability: identical before/after unlock.
        let d = registry::cmp170hx();
        let unlocked = d.clone().with_throttle(crate::device::ThrottleProfile::native());
        assert_eq!(d.fp32_tflops(), unlocked.fp32_tflops());
    }
}
