//! Step scheduling across in-flight sequences, plus the continuous-batching
//! admission (page-join) and eviction-planning steps.
//!
//! The decode loop must decide which active sequences advance each
//! iteration. Two policies:
//! - [`StepPolicy::RoundRobin`] — fair interleaving (latency-balanced);
//! - [`StepPolicy::ShortestFirst`] — drain sequences closest to completion
//!   first (frees KV pages sooner; throughput-biased under page pressure).
//!
//! Between rounds, [`plan_admission`] decides how many queued requests may
//! join the in-flight set — the vLLM-style join that replaced the old
//! batch-window-then-drain loop, now gated on free KV **pages** rather
//! than worst-case slots. When a round cannot allocate the growth pages
//! its sequences need, [`plan_eviction`] picks the preemption victim: the
//! longest-remaining sequence is dropped back to the waiting queue (KV
//! freed, prefill recomputed on resume) so short requests keep completing
//! instead of starving behind a long generation.
//! [`plan_eviction_weighted`] additionally breaks remaining-length ties
//! by the owner tenant's service surplus, extending WFQ fairness into the
//! KV pager.
//!
//! Eviction is also **cost-aware**: [`choose_preempt`] prices what a
//! victim's comeback costs each way — replaying prefill + generated
//! tokens at the node's calibrated overlay rates, versus round-tripping
//! its KV pages over the card's (often x1/x4-crippled) PCIe link via the
//! §3 model — and picks the cheaper. A 170HX on a stock link swaps long
//! sequences (decode replay dwarfs the transfer) but recomputes short
//! ones whose prefill replay is cheaper than the DMA; an x16-modded card
//! swaps almost everything. Recompute burns GPU joules where a swap burns
//! link time, so this is the scheduler-level version of the paper's
//! power-aware evaluation stance.

use crate::memhier::pcie::PcieLink;

use super::batcher::BatchPolicy;

/// An in-flight sequence the scheduler sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqView {
    pub seq: usize,
    pub generated: usize,
    pub target: usize,
}

impl SeqView {
    pub fn remaining(&self) -> usize {
        self.target.saturating_sub(self.generated)
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Scheduling policy for the decode loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPolicy {
    RoundRobin,
    ShortestFirst,
}

/// Order the active (not-done) sequences for the next decode round, writing
/// the plan into a caller-provided buffer. The decode loop calls this every
/// round — reusing `out` makes a planned round allocation-free after the
/// first (no intermediate `Vec<&SeqView>`, no fresh result `Vec`).
pub fn plan_round_into(policy: StepPolicy, seqs: &[SeqView], out: &mut Vec<usize>) {
    out.clear();
    // Positions first (so the sort key is an O(1) slice lookup), then map
    // in place to sequence ids — one buffer, zero transient allocations.
    out.extend(
        seqs.iter()
            .enumerate()
            .filter(|(_, s)| !s.done())
            .map(|(i, _)| i),
    );
    if policy == StepPolicy::ShortestFirst {
        // Stable sort: ties keep submission order, as before.
        out.sort_by_key(|&i| seqs[i].remaining());
    }
    for slot in out.iter_mut() {
        *slot = seqs[*slot].seq;
    }
}

/// Order the active (not-done) sequences for the next decode round.
/// Allocating convenience over [`plan_round_into`].
pub fn plan_round(policy: StepPolicy, seqs: &[SeqView]) -> Vec<usize> {
    let mut out = Vec::with_capacity(seqs.len());
    plan_round_into(policy, seqs, &mut out);
    out
}

/// The admission (page-join) step of continuous batching: how many queued
/// requests may join the decode round right now. Bounded by the policy's
/// concurrency cap and by `admissible` — the number of prefill windows
/// the KV pager's free pool could hold. Admission only fills headroom;
/// creating headroom mid-flight is [`plan_eviction`]'s job.
pub fn plan_admission(policy: &BatchPolicy, live: usize, admissible: usize) -> usize {
    policy.concurrency().saturating_sub(live).min(admissible)
}

/// The degradation ladder's admission step: scale a worker's concurrency
/// cap to its surviving KV pool after VRAM page loss. A card that lost a
/// quarter of its blocks admits a quarter fewer concurrent sequences
/// (rounded down, floored at one so the node keeps serving) instead of
/// discovering the shortfall as page-pressure thrash mid-flight.
pub fn degraded_concurrency(base_cap: usize, capacity_blocks: usize, base_blocks: usize) -> usize {
    if base_blocks == 0 || capacity_blocks >= base_blocks {
        return base_cap.max(1);
    }
    (base_cap * capacity_blocks / base_blocks).max(1)
}

/// Pick the preemption victim under KV page pressure: the **longest-
/// remaining** active sequence, ties broken toward the latest index (the
/// most recently admitted) — the inverse of [`StepPolicy::ShortestFirst`]'s
/// step order, so the work closest to completion is never thrown away.
/// Returns an index into `seqs`, or `None` when every sequence is done.
pub fn plan_eviction(seqs: &[SeqView]) -> Option<usize> {
    plan_eviction_shielded(seqs, &[])
}

/// [`plan_eviction`] with an eviction shield: `shielded[i]` marks
/// sequences that resumed through the waiting queue's aging gate and must
/// not bounce straight back to it (the park → age → resume → re-evict
/// livelock). Shielded sequences are victims of last resort: they are
/// picked only when no unshielded active sequence exists, so the shield
/// bounds starvation without sacrificing engine liveness. Indices past
/// `shielded`'s length are unshielded.
pub fn plan_eviction_shielded(seqs: &[SeqView], shielded: &[bool]) -> Option<usize> {
    plan_eviction_weighted(seqs, shielded, &[])
}

/// [`plan_eviction_shielded`] with tenant-aware tie breaking: `overserve[i]`
/// is the owning tenant's weight-normalized service so far (tokens served
/// ÷ WFQ weight — the surplus the deficit-round-robin queue meters).
/// Remaining length still governs (never throw away nearly-done work),
/// but **at equal remaining length the most over-served tenant's sequence
/// is evicted first**, extending admission-side fairness into the KV
/// pager. Missing entries read as zero surplus; final ties still break
/// toward the latest admission.
pub fn plan_eviction_weighted(
    seqs: &[SeqView],
    shielded: &[bool],
    overserve: &[f64],
) -> Option<usize> {
    let surplus = |i: usize| overserve.get(i).copied().unwrap_or(0.0);
    let pick = |all: bool| {
        seqs.iter()
            .enumerate()
            .filter(|&(i, s)| !s.done() && (all || !shielded.get(i).copied().unwrap_or(false)))
            .max_by(|&(i, a), &(j, b)| {
                a.remaining()
                    .cmp(&b.remaining())
                    .then(surplus(i).total_cmp(&surplus(j)))
                    .then(i.cmp(&j))
            })
            .map(|(i, _)| i)
    };
    pick(false).or_else(|| pick(true))
}

/// How a preemption victim should come back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptAction {
    /// Drop the KV; recompute prefill and replay generated tokens on
    /// resume (PR 3's only path).
    Recompute,
    /// Park the KV pages in host RAM over PCIe; restore them on resume.
    Swap,
}

/// Simulated seconds to round-trip `kv_bytes` of pages over `link` — the
/// §3 PCIe model priced at the card's actual lane width (swap-out now,
/// swap-in at resume).
pub fn swap_round_trip_s(kv_bytes: u64, link: &PcieLink) -> f64 {
    2.0 * link.transfer_time(kv_bytes)
}

/// Choose the cheaper comeback for a preemption victim: round-tripping
/// `kv_bytes` over this card's host link, or `recompute_s` of device time
/// replaying the sequence (prefill window + generated tokens, priced by
/// the node's calibrated overlay). Ties go to recompute — it needs no
/// host-pool reservation.
pub fn choose_preempt(kv_bytes: u64, link: &PcieLink, recompute_s: f64) -> PreemptAction {
    if swap_round_trip_s(kv_bytes, link) < recompute_s {
        PreemptAction::Swap
    } else {
        PreemptAction::Recompute
    }
}

/// Total decode rounds a batch needs (the longest target governs — decode
/// is serial per sequence).
pub fn rounds_needed(seqs: &[SeqView]) -> usize {
    seqs.iter().map(|s| s.remaining()).max().unwrap_or(0)
}

/// Split a swap/migration DMA into the part hidden under the ongoing
/// decode round and the part the engine actually stalls for. PCIe DMA
/// and SM compute proceed concurrently, so while other sequences keep
/// decoding (`round_s` of device time), the transfer costs the engine
/// nothing; only the overhang past the round stalls it. On an x1 card
/// the transfer dwarfs the round and almost everything stalls anyway —
/// the per-card overlap factor *is* the link-width story of §3. Returns
/// `(overlapped_s, stalled_s)`, summing to `transfer_s`; energy is the
/// caller's problem (the link burns joules for the full transfer either
/// way).
pub fn overlap_transfer(transfer_s: f64, round_s: f64) -> (f64, f64) {
    let overlapped = transfer_s.min(round_s.max(0.0));
    (overlapped, transfer_s - overlapped)
}

/// Prefix-aware admission: [`plan_admission`] prices every queued prompt
/// as `window_blocks` fresh pages, so at the capacity edge (`admissible
/// == 0`) it never pops a request whose prompt is mostly resident. When
/// plain admission stalls but a scanned request's window has
/// `resident_blocks` already in the radix tree (the pager's read-only
/// [`crate::coordinator::kv::KvPager::resident_prefix_blocks`] probe —
/// which counts warm-but-idle cached blocks too), admit it iff the
/// *fresh* remainder fits in free plus reclaimable pages: the admission
/// math distinguishes the three tiers — pinned pages are untouchable,
/// `free_blocks` are free, and `cached_blocks` are admissible at the
/// price of an LRU reclaim. `admit_prompt` re-checks the same
/// arithmetic authoritatively under its own lock (a stale probe costs
/// one bounced admission, never an over-commit).
pub fn plan_admission_prefix_aware(
    policy: &BatchPolicy,
    live: usize,
    admissible: usize,
    free_blocks: usize,
    cached_blocks: usize,
    window_blocks: usize,
    resident_blocks: usize,
) -> usize {
    let plain = plan_admission(policy, live, admissible);
    if plain > 0 || policy.concurrency() <= live {
        return plain;
    }
    let fresh = window_blocks.saturating_sub(resident_blocks);
    (resident_blocks > 0 && fresh <= free_blocks + cached_blocks) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn seq(seq: usize, generated: usize, target: usize) -> SeqView {
        SeqView {
            seq,
            generated,
            target,
        }
    }

    #[test]
    fn round_robin_preserves_order_and_skips_done() {
        let seqs = [seq(0, 2, 4), seq(1, 3, 3), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::RoundRobin, &seqs), vec![0, 2]);
    }

    #[test]
    fn shortest_first_orders_by_remaining() {
        let seqs = [seq(0, 0, 9), seq(1, 0, 2), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::ShortestFirst, &seqs), vec![1, 2, 0]);
    }

    #[test]
    fn admission_fills_headroom_without_preempting() {
        let p = |max_batch| BatchPolicy { max_batch, ..Default::default() };
        // room under both bounds → admit the smaller of the two
        assert_eq!(plan_admission(&p(4), 1, 8), 3);
        assert_eq!(plan_admission(&p(8), 1, 2), 2);
        // at the cap or out of slots → nothing joins
        assert_eq!(plan_admission(&p(4), 4, 4), 0);
        assert_eq!(plan_admission(&p(4), 0, 0), 0);
        // over-cap live set (cap lowered mid-flight) must not underflow
        assert_eq!(plan_admission(&p(2), 5, 3), 0);
        // zero cap is floored to one sequence
        assert_eq!(plan_admission(&p(0), 0, 3), 1);
    }

    #[test]
    fn degraded_concurrency_tracks_surviving_blocks() {
        // lost a quarter of 16 blocks → cap 8 shrinks to 6
        assert_eq!(degraded_concurrency(8, 12, 16), 6);
        // no loss (or growth) keeps the base cap
        assert_eq!(degraded_concurrency(8, 16, 16), 8);
        assert_eq!(degraded_concurrency(8, 20, 16), 8);
        // catastrophic loss floors at one so the node keeps serving
        assert_eq!(degraded_concurrency(8, 1, 16), 1);
        assert_eq!(degraded_concurrency(8, 0, 16), 1);
        // degenerate base pool never divides by zero
        assert_eq!(degraded_concurrency(4, 3, 0), 4);
    }

    #[test]
    fn eviction_picks_longest_remaining() {
        let seqs = [seq(0, 1, 4), seq(1, 0, 9), seq(2, 2, 5)];
        assert_eq!(plan_eviction(&seqs), Some(1));
    }

    #[test]
    fn eviction_breaks_ties_toward_the_latest_admission() {
        // equal remaining work → the most recently admitted goes back
        let seqs = [seq(0, 0, 5), seq(1, 2, 7), seq(2, 1, 6)];
        assert_eq!(plan_eviction(&seqs), Some(2));
    }

    #[test]
    fn eviction_skips_done_sequences() {
        let seqs = [seq(0, 9, 9), seq(1, 1, 3), seq(2, 5, 5)];
        assert_eq!(plan_eviction(&seqs), Some(1));
        assert_eq!(plan_eviction(&[seq(0, 4, 4)]), None);
        assert_eq!(plan_eviction(&[]), None);
    }

    #[test]
    fn shielded_sequences_are_victims_of_last_resort() {
        let seqs = [seq(0, 0, 9), seq(1, 0, 5), seq(2, 0, 7)];
        // unshielded: the longest-remaining (seq 0) goes
        assert_eq!(plan_eviction_shielded(&seqs, &[false, false, false]), Some(0));
        // shielding the longest redirects the eviction to the next-longest
        assert_eq!(plan_eviction_shielded(&seqs, &[true, false, false]), Some(2));
        // everything shielded: liveness wins — longest-remaining again
        assert_eq!(plan_eviction_shielded(&seqs, &[true, true, true]), Some(0));
        // a short shield slice leaves the tail unshielded
        assert_eq!(plan_eviction_shielded(&seqs, &[true]), Some(2));
        // done sequences are never victims even when all actives shielded
        let seqs = [seq(0, 9, 9), seq(1, 0, 5)];
        assert_eq!(plan_eviction_shielded(&seqs, &[false, true]), Some(1));
    }

    #[test]
    fn weighted_eviction_prefers_the_over_served_tenant_at_equal_length() {
        // Three sequences with equal remaining work, owned by tenants with
        // normalized service 10, 250, and 40 tokens/weight: the most
        // over-served tenant's sequence goes back to the queue first.
        let seqs = [seq(0, 1, 6), seq(1, 2, 7), seq(2, 0, 5)];
        assert_eq!(plan_eviction_weighted(&seqs, &[], &[10.0, 250.0, 40.0]), Some(1));
        // remaining length still dominates the surplus…
        let seqs = [seq(0, 0, 9), seq(1, 2, 7), seq(2, 0, 5)];
        assert_eq!(plan_eviction_weighted(&seqs, &[], &[0.0, 250.0, 40.0]), Some(0));
        // …the shield still outranks the surplus…
        let seqs = [seq(0, 1, 6), seq(1, 2, 7), seq(2, 0, 5)];
        assert_eq!(
            plan_eviction_weighted(&seqs, &[false, true, false], &[10.0, 250.0, 40.0]),
            Some(2)
        );
        // …and with no surplus data the old latest-admission tie-break holds
        assert_eq!(plan_eviction_weighted(&seqs, &[], &[]), Some(2));
    }

    #[test]
    fn swap_chooser_prices_pcie_against_recompute_at_x1_and_x16() {
        use crate::device::registry;
        // A 170HX's KV footprint for a ~1k-position sequence: ~29 MB.
        let kv_bytes: u64 = 1024 * 28_672;
        let x1 = registry::cmp170hx().pcie.with_lanes(1);
        let x16 = registry::cmp170hx().pcie.with_lanes(16);
        let (t1, t16) = (swap_round_trip_s(kv_bytes, &x1), swap_round_trip_s(kv_bytes, &x16));
        assert!(t1 > t16, "narrower link, slower swap: {t1} vs {t16}");
        // A recompute estimate between the two transfer times: the x1 card
        // recomputes this sequence, the x16-modded card swaps it.
        let recompute_s = (t1 + t16) / 2.0;
        assert_eq!(choose_preempt(kv_bytes, &x1, recompute_s), PreemptAction::Recompute);
        assert_eq!(choose_preempt(kv_bytes, &x16, recompute_s), PreemptAction::Swap);
        // On the same x1 link, a long sequence (decode replay dominates the
        // recompute estimate) swaps while a short one recomputes — the
        // per-victim decision the engine makes.
        let (prefill_s, decode_s) = (0.2e-3, 40e-3); // per token, 170HX-ish
        let cost = |prefill_t: usize, replay: usize| {
            prefill_s * prefill_t as f64 + decode_s * replay as f64
        };
        let bytes = |positions: u64| positions * 28_672;
        assert_eq!(
            choose_preempt(bytes(512), &x1, cost(512, 0)),
            PreemptAction::Recompute,
            "a fresh-out-of-prefill victim replays cheaper than the x1 DMA"
        );
        assert_eq!(
            choose_preempt(bytes(1024), &x1, cost(512, 512)),
            PreemptAction::Swap,
            "half a second of decode replay dwarfs the x1 transfer"
        );
    }

    #[test]
    fn prop_swap_chooser_matches_the_cost_comparison() {
        use crate::memhier::pcie::{PcieGen, PcieLink};
        forall(0x5A9, 300, |rng: &mut Rng| {
            let gen = *rng.pick(&[PcieGen::Gen1, PcieGen::Gen2, PcieGen::Gen3, PcieGen::Gen4]);
            let link = PcieLink::new(gen, rng.range(1, 17) as u32);
            let kv_bytes = rng.range(0, 1 << 28);
            let recompute_s = rng.f64_range(0.0, 2.0);
            let want = if swap_round_trip_s(kv_bytes, &link) < recompute_s {
                PreemptAction::Swap
            } else {
                PreemptAction::Recompute
            };
            assert_eq!(choose_preempt(kv_bytes, &link, recompute_s), want);
        });
    }

    #[test]
    fn prop_eviction_victim_is_never_shorter_than_a_survivor() {
        forall(0xE71C7, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| seq(i, rng.range(0, 8) as usize, rng.range(0, 8) as usize))
                .collect();
            match plan_eviction(&seqs) {
                Some(v) => {
                    assert!(!seqs[v].done(), "victim must be active");
                    for s in seqs.iter().filter(|s| !s.done()) {
                        assert!(
                            seqs[v].remaining() >= s.remaining(),
                            "victim {} outlived by seq {}",
                            seqs[v].seq,
                            s.seq
                        );
                    }
                }
                None => assert!(seqs.iter().all(|s| s.done())),
            }
        });
    }

    #[test]
    fn overlap_splits_transfer_against_the_decode_round() {
        // transfer shorter than the round: fully hidden, zero stall
        let (o, s) = overlap_transfer(0.2, 1.0);
        assert_eq!((o, s), (0.2, 0.0));
        // transfer longer than the round: the overhang stalls
        let (o, s) = overlap_transfer(1.0, 0.3);
        assert!((o - 0.3).abs() < 1e-12 && (s - 0.7).abs() < 1e-12);
        // no concurrent decode (idle card, or overlap disabled upstream):
        // everything stalls — the serial-charge baseline
        assert_eq!(overlap_transfer(0.5, 0.0), (0.0, 0.5));
        assert_eq!(overlap_transfer(0.5, -1.0), (0.0, 0.5));
        // the split always conserves the transfer
        assert_eq!(overlap_transfer(0.0, 1.0), (0.0, 0.0));
    }

    #[test]
    fn x1_overlap_stall_is_strictly_below_the_serial_charge() {
        // The ISSUE 7 overlap acceptance point, pinned analytically: a
        // 170HX on its crippled x1 link swaps a ~1k-position sequence's
        // private KV while three other sequences run a 170HX-priced
        // decode round. The stalled seconds the engine charges must be
        // strictly below the serial-charge baseline (the full transfer,
        // what PR 5 booked), and on x1 the overlap factor is small — the
        // transfer dwarfs the round, which is exactly the §3 story.
        use crate::device::registry;
        let x1 = registry::cmp170hx().pcie.with_lanes(1);
        let transfer_s = x1.transfer_time(1024 * 28_672);
        let round_s = 40e-3 * 3.0; // ~40 ms/token decode, 3 concurrent seqs
        let (overlapped, stalled) = overlap_transfer(transfer_s, round_s);
        assert!(stalled < transfer_s, "overlap must beat the serial charge");
        assert!(stalled > 0.0, "an x1 transfer cannot hide entirely");
        assert!((overlapped + stalled - transfer_s).abs() < 1e-12);
        assert_eq!(overlapped, round_s, "the whole round hides transfer on x1");
        // an x16-modded card flips the regime: the same bytes hide
        // completely under the same round
        let x16 = registry::cmp170hx().pcie.with_lanes(16);
        let t16 = x16.transfer_time(1024 * 28_672);
        if t16 <= round_s {
            assert_eq!(overlap_transfer(t16, round_s), (t16, 0.0));
        }
    }

    #[test]
    fn prefix_aware_admission_opens_the_capacity_edge() {
        let p = |max_batch| BatchPolicy { max_batch, ..Default::default() };
        // plain admission already flows → unchanged, probe ignored
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 2, 64, 0, 64, 64), 2);
        // capacity edge (no full window fits) but the head's prompt is
        // mostly resident: its fresh remainder fits → admit exactly one
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 32, 0, 64, 32), 1);
        // fully-resident head needs zero fresh blocks
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 0, 0, 64, 64), 1);
        // no resident prefix → the gate stays closed (prefix-blind path)
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 32, 0, 64, 0), 0);
        // resident but the fresh tail still overflows the pool → closed
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 16, 0, 64, 32), 0);
        // …unless the cached tier covers the shortfall: idle cached
        // pages are admissible at the price of a reclaim
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 16, 16, 64, 32), 1);
        // cached pages alone never open the gate for a prefix-less
        // prompt — the prefix-blind path stays plain
        assert_eq!(plan_admission_prefix_aware(&p(4), 1, 0, 32, 64, 64, 0), 0);
        // concurrency cap still binds even with a resident prompt
        assert_eq!(plan_admission_prefix_aware(&p(2), 2, 0, 64, 0, 64, 64), 0);
    }

    #[test]
    fn rounds_needed_is_max_remaining() {
        let seqs = [seq(0, 1, 4), seq(1, 0, 2)];
        assert_eq!(rounds_needed(&seqs), 3);
        assert_eq!(rounds_needed(&[]), 0);
    }

    #[test]
    fn plan_round_into_reuses_the_buffer() {
        let mut buf = vec![99, 98, 97, 96]; // stale garbage must be cleared
        let seqs = [seq(0, 0, 9), seq(1, 0, 2), seq(2, 3, 3)];
        plan_round_into(StepPolicy::ShortestFirst, &seqs, &mut buf);
        assert_eq!(buf, vec![1, 0]);
        plan_round_into(StepPolicy::RoundRobin, &seqs, &mut buf);
        assert_eq!(buf, vec![0, 1]);
    }

    #[test]
    fn prop_plan_round_into_matches_plan_round() {
        forall(0xB0F, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| seq(i, rng.range(0, 8) as usize, rng.range(0, 8) as usize))
                .collect();
            let policy = if rng.chance(0.5) {
                StepPolicy::RoundRobin
            } else {
                StepPolicy::ShortestFirst
            };
            let mut buf = Vec::new();
            plan_round_into(policy, &seqs, &mut buf);
            assert_eq!(buf, plan_round(policy, &seqs));
        });
    }

    #[test]
    fn prop_every_unfinished_sequence_is_planned_exactly_once() {
        forall(0x5C_ED, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| {
                    let target = rng.range(0, 8) as usize;
                    seq(i, rng.range(0, 8) as usize, target)
                })
                .collect();
            let policy = if rng.chance(0.5) {
                StepPolicy::RoundRobin
            } else {
                StepPolicy::ShortestFirst
            };
            let plan = plan_round(policy, &seqs);
            let expected: Vec<usize> =
                seqs.iter().filter(|s| !s.done()).map(|s| s.seq).collect();
            let mut sorted = plan.clone();
            sorted.sort_unstable();
            let mut exp_sorted = expected.clone();
            exp_sorted.sort_unstable();
            assert_eq!(sorted, exp_sorted, "plan must cover active set exactly");
        });
    }
}
