//! Fleet router: spread requests across multiple (simulated) cards.
//!
//! §6.2 imagines community edge nodes built from recycled CMP cards; a
//! node with several cards needs a router. Policies:
//! - [`RoutePolicy::RoundRobin`] — classic;
//! - [`RoutePolicy::LeastLoaded`] — by outstanding work;
//! - [`RoutePolicy::WeightedThroughput`] — by each card's decode tokens/s
//!   (heterogeneous fleets: a 170HX next to a 90HX).

use crate::device::DeviceSpec;
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::llm::quant::QuantFormat;

/// One routed card.
#[derive(Clone, Debug)]
pub struct Node {
    pub name: &'static str,
    /// Decode throughput weight (tokens/s on the serving quant).
    pub weight: f64,
    /// Outstanding queued work units.
    pub outstanding: u64,
    /// Cumulative assigned requests.
    pub assigned: u64,
    /// Routable. The dispatch stage clears this when the node's worker is
    /// gone (its queue rejected a send), excluding it from future routing
    /// — the old behaviour kept selecting the dead card forever while
    /// healthy ones idled.
    pub healthy: bool,
}

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    WeightedThroughput,
}

/// A fleet of cards plus a routing cursor.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub nodes: Vec<Node>,
    policy: RoutePolicy,
    cursor: usize,
}

impl Fleet {
    /// Build a fleet directly from pre-weighted nodes — the serving engine
    /// computes weights once from its per-node calibrated bench rows and
    /// hands them over, making the router the actual dispatch stage rather
    /// than a standalone index-picker.
    pub fn new(nodes: Vec<Node>, policy: RoutePolicy) -> Self {
        Fleet {
            nodes,
            policy,
            cursor: 0,
        }
    }

    /// Build a fleet from device specs, weighting by simulated decode
    /// throughput on `quant` at `policy`'s fmad setting. The weighting
    /// kernels are lowered once and swept across the whole fleet as one
    /// batched [`crate::sim::batch`] run — fleet size no longer multiplies
    /// IR walks.
    pub fn from_devices(
        devices: &[DeviceSpec],
        quant: &QuantFormat,
        fmad: FmadPolicy,
        policy: RoutePolicy,
    ) -> Self {
        let bench = LlamaBench::default();
        let nodes = devices
            .iter()
            .zip(bench.run_across(devices, quant, fmad))
            .map(|(d, r)| Node {
                name: d.name,
                weight: r.decode_tps,
                outstanding: 0,
                assigned: 0,
                healthy: true,
            })
            .collect();
        Fleet {
            nodes,
            policy,
            cursor: 0,
        }
    }

    /// Uniform fleet of `n` identical nodes (tests/benches).
    pub fn uniform(n: usize, weight: f64, policy: RoutePolicy) -> Self {
        Fleet {
            nodes: (0..n)
                .map(|_| Node {
                    name: "node",
                    weight,
                    outstanding: 0,
                    assigned: 0,
                    healthy: true,
                })
                .collect(),
            policy,
            cursor: 0,
        }
    }

    /// Route one request; returns the node index. Unhealthy nodes are
    /// skipped while at least one healthy node remains; a fully-unhealthy
    /// fleet degrades to routing across all nodes (standalone callers keep
    /// working — the dispatch stage checks [`Fleet::healthy_count`] itself
    /// and fails requests instead of sending them to the dead).
    pub fn route(&mut self) -> usize {
        assert!(!self.nodes.is_empty(), "empty fleet");
        let all = self.healthy_count() == 0;
        let eligible = |n: &Node| all || n.healthy;
        let idx = match self.policy {
            RoutePolicy::RoundRobin => loop {
                let i = self.cursor % self.nodes.len();
                self.cursor += 1;
                if eligible(&self.nodes[i]) {
                    break i;
                }
            },
            RoutePolicy::LeastLoaded => self
                .nodes
                .iter()
                .enumerate()
                .filter(|&(_, n)| eligible(n))
                .min_by_key(|(_, n)| n.outstanding)
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::WeightedThroughput => {
                // pick the node with the lowest normalized load
                // (outstanding / weight) — deterministic weighted fairness.
                self.nodes
                    .iter()
                    .enumerate()
                    .filter(|&(_, n)| eligible(n))
                    .min_by(|(_, a), (_, b)| {
                        let la = (a.outstanding as f64 + 1.0) / a.weight.max(1e-9);
                        let lb = (b.outstanding as f64 + 1.0) / b.weight.max(1e-9);
                        la.partial_cmp(&lb).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap()
            }
        };
        self.nodes[idx].outstanding += 1;
        self.nodes[idx].assigned += 1;
        idx
    }

    /// Mark one unit of work complete on a node.
    pub fn complete(&mut self, idx: usize) {
        assert!(self.nodes[idx].outstanding > 0, "complete on idle node");
        self.nodes[idx].outstanding -= 1;
    }

    /// Exclude a node from routing — its worker is gone or an operator
    /// drained it. Reversed by [`Fleet::mark_healthy`].
    pub fn mark_unhealthy(&mut self, idx: usize) {
        self.nodes[idx].healthy = false;
    }

    /// Restore a node to the routable set — the recovery hook the old
    /// router lacked (an excluded node stayed excluded for the server's
    /// lifetime even after its worker came back or an operator replaced
    /// the card). The dispatch stage resumes routing to it on the next
    /// request.
    pub fn mark_healthy(&mut self, idx: usize) {
        self.nodes[idx].healthy = true;
    }

    /// Move one queued unit of work from `from` to `to` — the router-side
    /// bookkeeping of a work steal. The request was routed (and counted)
    /// onto `from` but will be served (and completed) by `to`.
    pub fn reassign(&mut self, from: usize, to: usize) {
        assert!(self.nodes[from].outstanding > 0, "reassign from an idle node");
        self.nodes[from].outstanding -= 1;
        self.nodes[from].assigned -= 1;
        self.nodes[to].outstanding += 1;
        self.nodes[to].assigned += 1;
    }

    /// Nodes still eligible for routing.
    pub fn healthy_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.healthy).count()
    }

    pub fn total_assigned(&self) -> u64 {
        self.nodes.iter().map(|n| n.assigned).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    #[test]
    fn round_robin_cycles() {
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| f.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_fills_idle_nodes_first() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::LeastLoaded);
        let a = f.route();
        let b = f.route();
        assert_ne!(a, b);
        f.complete(a);
        assert_eq!(f.route(), a);
    }

    #[test]
    fn weighted_routing_respects_throughput_ratios() {
        // node 0 twice as fast → gets ~2/3 of a long stream.
        let mut f = Fleet::new(
            vec![node("fast", 200.0), node("slow", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        // steady state: each node drains work at its own speed
        let mut service = [0.0f64; 2];
        for _ in 0..3000 {
            let _ = f.route();
            for (i, s) in service.iter_mut().enumerate() {
                *s += f.nodes[i].weight / 300.0;
                while *s >= 1.0 && f.nodes[i].outstanding > 0 {
                    f.complete(i);
                    *s -= 1.0;
                }
            }
        }
        let fast = f.nodes[0].assigned as f64;
        let slow = f.nodes[1].assigned as f64;
        let ratio = fast / slow;
        assert!(ratio > 1.6 && ratio < 2.5, "{ratio}");
    }

    fn node(name: &'static str, weight: f64) -> Node {
        Node {
            name,
            weight,
            outstanding: 0,
            assigned: 0,
            healthy: true,
        }
    }

    #[test]
    fn weighted_routing_starves_zero_weight_nodes() {
        // A dead card (zero measured throughput) must not attract traffic:
        // its normalized load is effectively infinite.
        let mut f = Fleet::new(
            vec![node("dead", 0.0), node("live", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        for _ in 0..50 {
            assert_eq!(f.route(), 1);
        }
        assert_eq!(f.nodes[0].assigned, 0);
        assert_eq!(f.nodes[1].assigned, 50);
    }

    #[test]
    fn weighted_all_zero_weight_fleet_still_routes() {
        // Degenerate fleet: every weight zero. The epsilon guard keeps the
        // load metric finite, so routing degrades to least-loaded instead
        // of panicking on a NaN comparison.
        let mut f = Fleet::new(
            vec![node("a", 0.0), node("b", 0.0)],
            RoutePolicy::WeightedThroughput,
        );
        for _ in 0..4 {
            let i = f.route();
            assert!(i < 2);
        }
        assert_eq!(f.total_assigned(), 4);
        assert_eq!(f.nodes[0].assigned, 2);
        assert_eq!(f.nodes[1].assigned, 2);
    }

    #[test]
    fn weighted_single_node_fleet_routes_everything_to_it() {
        let mut f = Fleet::uniform(1, 5.0, RoutePolicy::WeightedThroughput);
        for _ in 0..10 {
            assert_eq!(f.route(), 0);
        }
        assert_eq!(f.nodes[0].assigned, 10);
        assert_eq!(f.nodes[0].outstanding, 10);
    }

    #[test]
    #[should_panic(expected = "empty fleet")]
    fn empty_fleet_route_panics() {
        let mut f = Fleet::uniform(0, 1.0, RoutePolicy::WeightedThroughput);
        let _ = f.route();
    }

    #[test]
    fn heterogeneous_fleet_from_registry() {
        use crate::device::registry;
        use crate::llm::quant;
        let f = Fleet::from_devices(
            &[registry::cmp170hx(), registry::cmp170hx_x16()],
            &quant::Q4_K_M,
            FmadPolicy::Decomposed,
            RoutePolicy::WeightedThroughput,
        );
        assert_eq!(f.nodes.len(), 2);
        // the x16 mod lowers readback overhead → strictly faster decode
        assert!(f.nodes[1].weight > f.nodes[0].weight);
    }

    #[test]
    fn unhealthy_nodes_are_excluded_from_every_policy() {
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::WeightedThroughput,
        ] {
            let mut f = Fleet::uniform(3, 1.0, policy);
            f.mark_unhealthy(1);
            assert_eq!(f.healthy_count(), 2);
            for _ in 0..12 {
                let i = f.route();
                assert_ne!(i, 1, "{policy:?} routed to a dead node");
            }
            assert_eq!(f.nodes[1].assigned, 0);
        }
    }

    #[test]
    fn round_robin_keeps_cycling_the_survivors() {
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(0);
        let picks: Vec<usize> = (0..4).map(|_| f.route()).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn fully_unhealthy_fleet_degrades_instead_of_hanging() {
        // route() must not spin or panic when every node is dead; the
        // dispatch stage guards on healthy_count() before trusting it.
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(0);
        f.mark_unhealthy(1);
        assert_eq!(f.healthy_count(), 0);
        let i = f.route();
        assert!(i < 2);
    }

    #[test]
    fn recovered_nodes_rejoin_routing() {
        // Regression: there was no mark_healthy — a node excluded once
        // stayed excluded forever, so a fleet that lost and regained a
        // card kept idling it.
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(1);
        for _ in 0..4 {
            assert_eq!(f.route(), 0);
        }
        f.mark_healthy(1);
        assert_eq!(f.healthy_count(), 2);
        let picks: Vec<usize> = (0..4).map(|_| f.route()).collect();
        assert!(picks.contains(&1), "recovered node must serve again: {picks:?}");
    }

    #[test]
    fn reassign_moves_outstanding_and_assigned() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        assert_eq!(f.route(), 0);
        assert_eq!(f.route(), 1);
        assert_eq!(f.route(), 0);
        // node 1 steals one of node 0's queued requests
        f.reassign(0, 1);
        assert_eq!(f.nodes[0].outstanding, 1);
        assert_eq!(f.nodes[1].outstanding, 2);
        assert_eq!(f.nodes[0].assigned, 1);
        assert_eq!(f.nodes[1].assigned, 2);
        assert_eq!(f.total_assigned(), 3, "steals conserve the request count");
        // the thief completes the stolen work
        f.complete(1);
        f.complete(1);
        assert_eq!(f.nodes[1].outstanding, 0);
    }

    #[test]
    #[should_panic(expected = "reassign from an idle node")]
    fn reassign_from_an_idle_node_panics() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.reassign(0, 1);
    }

    #[test]
    fn prop_routing_conserves_requests() {
        // Every request lands on exactly one node; totals match.
        forall(0x40B7E, 200, |rng: &mut Rng| {
            let n = rng.range(1, 6) as usize;
            let policy = *rng.pick(&[
                RoutePolicy::RoundRobin,
                RoutePolicy::LeastLoaded,
                RoutePolicy::WeightedThroughput,
            ]);
            let mut f = Fleet::uniform(n, 1.0, policy);
            let total = rng.range(1, 200);
            for _ in 0..total {
                let i = f.route();
                assert!(i < n);
                if rng.chance(0.6) {
                    f.complete(i);
                }
            }
            assert_eq!(f.total_assigned(), total);
            let sum: u64 = f.nodes.iter().map(|x| x.assigned).sum();
            assert_eq!(sum, total);
        });
    }
}
