//! Request/response types for the serving path.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::obsv::{PhaseLedger, TraceId};
use crate::qos::TenantId;

/// State a request accumulates across re-entries into the admission
/// queue — a node-death rescue or a bounded retry. Empty (the default) on
/// first submission; the worker folds it into the live sequence at
/// admission so a rescued request's final response reports the whole
/// journey, not just its last node.
#[derive(Clone, Debug, Default)]
pub struct Carried {
    /// Tokens already generated before the fault. Greedy decode is
    /// deterministic, so replaying these after a fresh prefill on the new
    /// card reconstructs a bit-identical decode state.
    pub replay: Vec<i32>,
    /// Phase timings and overlay charges accrued on previous nodes.
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    /// Simulated device seconds split by phase (prefill / decode / stall
    /// / replay) — the latency-attribution ledger.
    pub ledger: PhaseLedger,
    pub sim_j: f64,
    pub preemptions: u64,
    pub swaps: u64,
    /// Node deaths this request survived via rescue.
    pub rescues: u64,
    /// Dispatch retry attempts consumed (bounded by the recovery policy).
    pub attempt: u32,
}

impl Carried {
    /// Has this request been through a rescue or retry re-entry?
    pub fn is_replay(&self) -> bool {
        !self.replay.is_empty()
    }
}

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    /// The tenant this request bills to (fair-share lane, rate and energy
    /// caps). [`crate::coordinator::ServerHandle::submit`] uses the
    /// default tenant; `submit_as` attributes explicitly.
    pub tenant: TenantId,
    /// Prompt token ids (≤ the model's prefill window). These reach the
    /// worker's admission step intact: the prefix-sharing pager
    /// chain-hashes the padded prompt window block-by-block and pins
    /// already-resident blocks instead of allocating
    /// ([`crate::coordinator::kv::KvPager::admit_prompt`]).
    pub prompt: Vec<i32>,
    /// Tokens to generate (bounded by KV capacity at serve time).
    pub max_tokens: usize,
    /// Estimated simulated joules charged against the tenant's energy
    /// budget when the QoS dispatch stage routed this request (priced
    /// with the routed node's overlay); settled to actuals at retire.
    pub charged_j: f64,
    /// Where the response goes. Dropped receiver = cancelled request.
    pub reply: Sender<GenResponse>,
    /// Enqueue timestamp for latency accounting. Reset at each rescue or
    /// retry re-entry (the prior wait is banked in [`Carried::queue_s`]).
    pub enqueued: Instant,
    /// Wall-clock deadline stamped at submission — the tenant's SLO
    /// contract when one is declared (`name:weight:…:slo_ms`), else the
    /// server-wide recovery deadline; past it the request fails at the
    /// next dispatch or admission checkpoint instead of occupying a card.
    pub deadline: Option<Instant>,
    /// The tenant's SLO latency target in seconds, when contracted —
    /// what admission control predicts against at submit, and what the
    /// per-tenant attainment rollup scores at retire.
    pub slo_s: Option<f64>,
    /// Rescue/retry state carried across nodes (empty on first entry).
    pub carry: Carried,
}

/// The served result.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// The tenant the request billed to.
    pub tenant: TenantId,
    /// Generated token ids (empty on error).
    pub tokens: Vec<i32>,
    /// Error text if generation failed.
    pub error: Option<String>,
    /// Wall-clock queueing delay, seconds.
    pub queue_s: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_s: f64,
    /// Wall-clock decode time, seconds.
    pub decode_s: f64,
    /// Simulated device time for the same work on the serving card,
    /// seconds (the timing-model overlay; see DESIGN.md §E2E). The sum
    /// of [`GenResponse::ledger`]'s phases.
    pub simulated_device_s: f64,
    /// Per-phase split of the simulated device time: prefill vs decode
    /// vs swap-stall vs replay-recompute seconds.
    pub ledger: PhaseLedger,
    /// Times this request was preempted under KV page pressure and later
    /// resumed (each resume recomputed prefill and replayed the tokens
    /// generated so far — unless the eviction swapped, see
    /// [`GenResponse::swaps`]).
    pub preemptions: u64,
    /// Of those preemptions, how many parked the KV pages in host RAM
    /// over PCIe and restored them on resume instead of recomputing —
    /// chosen per victim when the §3 transfer model prices the round trip
    /// below the overlay's recompute estimate.
    pub swaps: u64,
    /// Node deaths this request survived: each rescue re-queued it off
    /// the dead card and replayed its generated tokens on a healthy one.
    pub rescues: u64,
    /// Fleet node index that served (or rejected) the request. Requests
    /// shed at the QoS dispatch stage (energy budget exhausted, no
    /// healthy node) report the node the router would have picked, or 0
    /// when routing never happened.
    pub node: usize,
    /// The request's trace id in the flight-recorder journal
    /// ([`crate::obsv`]): look up `"trace":N` lines (and the `[trace N]`
    /// suffix on error strings) to reconstruct this request's lifecycle.
    pub trace: TraceId,
}

impl GenResponse {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// End-to-end wall latency.
    pub fn latency_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn response_latency_sums_phases() {
        let r = GenResponse {
            id: 1,
            tenant: TenantId(0),
            tokens: vec![1, 2],
            error: None,
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.3,
            simulated_device_s: 0.05,
            ledger: PhaseLedger { prefill_s: 0.02, decode_s: 0.03, ..PhaseLedger::default() },
            preemptions: 0,
            swaps: 0,
            rescues: 0,
            node: 0,
            trace: TraceId(1),
        };
        assert!(r.ok());
        assert!((r.latency_s() - 0.6).abs() < 1e-12);
        assert!((r.ledger.device_s() - r.simulated_device_s).abs() < 1e-12);
    }

    #[test]
    fn fresh_requests_carry_no_replay_state() {
        let c = Carried::default();
        assert!(!c.is_replay());
        assert_eq!(c.attempt, 0);
        assert_eq!(c.rescues, 0);
        let replayed = Carried { replay: vec![4, 5], rescues: 1, ..Carried::default() };
        assert!(replayed.is_replay());
    }

    #[test]
    fn request_carries_reply_channel_and_tenant() {
        let (tx, rx) = channel();
        let req = GenRequest {
            id: 7,
            tenant: TenantId(2),
            prompt: vec![1, 2, 3],
            max_tokens: 4,
            charged_j: 0.0,
            reply: tx,
            enqueued: Instant::now(),
            deadline: None,
            slo_s: None,
            carry: Carried::default(),
        };
        req.reply
            .send(GenResponse {
                id: req.id,
                tenant: req.tenant,
                tokens: vec![9],
                error: None,
                queue_s: 0.0,
                prefill_s: 0.0,
                decode_s: 0.0,
                simulated_device_s: 0.0,
                ledger: PhaseLedger::default(),
                preemptions: 0,
                swaps: 0,
                rescues: 0,
                node: 0,
                trace: TraceId(7),
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tenant, TenantId(2));
        assert_eq!(resp.trace, TraceId(7));
    }
}
