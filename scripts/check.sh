#!/usr/bin/env bash
# Local tier-1 gate: build, test, lint.
#
# Usage: scripts/check.sh [--no-clippy | --chaos | --fabric | --cache | --trace | --load]
#
# Mirrors the ROADMAP tier-1 verify (`cargo build --release && cargo test
# -q`) and adds rustfmt drift detection plus clippy with warnings denied.
# Run from anywhere; the script cd's to the repo root.
#
# --chaos runs only the seeded chaos smoke: the integration_chaos suite
# once per seed in CHAOS_SEEDS (default "1 7 42"). Each seed replays a
# deterministic fault script against the 2-card fleet; a red seed is
# reproducible with `CHAOS_SEED=<n> cargo test --release --test
# integration_chaos`. (The suite self-skips without AOT artifacts, so the
# smoke is a compile-plus-determinism gate on artifact-less runners.)
#
# --fabric runs only the KV-fabric smoke: the integration_fabric suite
# (prefix-affine routing vs its ablation, live migration bit-identity,
# the dying-migration-target chaos case). Same self-skip rule.
#
# --cache runs only the radix-cache smoke: the integration_cache suite
# (returning-user KV resurrection vs the --no-kv-cache ablation, and
# cache reclaim under a tight page budget). Same self-skip rule.
#
# --trace runs only the observability smoke: the obsv unit suites (journal,
# exporters, byte-identical determinism) plus the integration_trace suite
# (chaos death → flight dump, journal roundtrip, rescued-lifecycle spans).
# Same self-skip rule for the integration half.
#
# --load runs only the overload smoke: the load unit suites (seeded
# arrival generators, the admission controller's brownout ladder, the
# discrete-event fleet model) plus the integration_load suite — the
# AC-vs-reactive knee comparison, below-knee bit-identity, and same-seed
# curve replay, all on the pure simulator so it is *fully* asserted even
# without AOT artifacts (only the one live-server test self-skips).

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a Rust toolchain to run the tier-1 gate" >&2
    exit 1
fi

if [[ "${1:-}" == "--chaos" ]]; then
    for seed in ${CHAOS_SEEDS:-1 7 42}; do
        echo "==> chaos smoke: CHAOS_SEED=$seed"
        CHAOS_SEED="$seed" cargo test --release --test integration_chaos -q
    done
    echo "chaos smoke passed"
    exit 0
fi

if [[ "${1:-}" == "--fabric" ]]; then
    echo "==> fabric smoke: cargo test --release --test integration_fabric"
    cargo test --release --test integration_fabric -q
    echo "fabric smoke passed"
    exit 0
fi

if [[ "${1:-}" == "--cache" ]]; then
    echo "==> cache smoke: cargo test --release --test integration_cache"
    cargo test --release --test integration_cache -q
    echo "cache smoke passed"
    exit 0
fi

if [[ "${1:-}" == "--load" ]]; then
    echo "==> load smoke: cargo test --release load::"
    cargo test --release -q load::
    echo "==> load smoke: cargo test --release --test integration_load"
    cargo test --release --test integration_load -q
    echo "load smoke passed"
    exit 0
fi

if [[ "${1:-}" == "--trace" ]]; then
    echo "==> trace smoke: cargo test --release obsv::"
    cargo test --release -q obsv::
    echo "==> trace smoke: cargo test --release --test integration_trace"
    cargo test --release --test integration_trace -q
    echo "trace smoke passed"
    exit 0
fi

# Formatting first: cheapest check, and drift must fail loudly (CI installs
# the rustfmt component, so the warning branch only fires on bare local
# toolchains).
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --all -- --check"
    cargo fmt --all -- --check
else
    echo "warning: rustfmt not installed; skipping format gate" >&2
fi

echo "==> cargo build --release --all-targets"
# --all-targets so benches and examples (which cargo test skips) cannot rot
cargo build --release --all-targets

echo "==> cargo test -q"
cargo test -q

if [[ "${1:-}" != "--no-clippy" ]]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "==> cargo clippy --all-targets -- -D warnings"
        cargo clippy --all-targets -- -D warnings
    else
        echo "warning: clippy not installed; skipping lint step" >&2
    fi
fi

echo "tier-1 gate passed"
