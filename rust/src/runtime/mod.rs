//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute from the
//! L3 hot path. Python never runs here — the artifacts are self-contained
//! (model weights are baked into the HLO as constants).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.

pub mod artifacts;
pub mod engine_rt;
pub mod goldens;

pub use artifacts::ArtifactDir;
pub use engine_rt::{DecodeState, ModelRuntime};

/// True when a live PJRT client can be constructed. False with the
/// vendored stub `xla` crate (no `xla_extension` in the build image) —
/// integration tests and the serving benches use this to skip gracefully
/// instead of failing on environments that cannot run the runtime at all.
pub fn pjrt_available() -> bool {
    xla::PjRtClient::cpu().is_ok()
}
