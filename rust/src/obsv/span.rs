//! Typed span events on the simulated clock.
//!
//! Every event the serving pipeline emits is a [`SpanEvent`]: a
//! [`SpanKind`] stamped with the emitting node, the node's engine round,
//! and the node's **simulated** clock ([`crate::obsv::Journal`] assigns
//! the per-ring sequence number). Wall time never appears in a span —
//! the simulated clock is derived purely from the calibrated overlay
//! charges, so a single-threaded replay of the same schedule produces a
//! byte-identical journal regardless of host speed (the determinism the
//! chaos smoke asserts). Requests are identified by [`TraceId`], the
//! server-assigned request id, which is also threaded into
//! [`crate::coordinator::GenResponse`] and error strings (`[trace N]`) so
//! a client can locate its journal lines from the failure it received.

use std::fmt;

/// One request's identity across every node it touches: the id the
/// server assigned at submission. Carried in
/// [`crate::coordinator::GenResponse::trace`] and appended to error
/// strings as `[trace N]`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

/// The reserved id for node-scoped events — decode rounds, faults,
/// series samples — that belong to no single request.
pub const NODE_SCOPE: TraceId = TraceId(u64::MAX);

impl TraceId {
    /// Is this the node-scoped pseudo-trace?
    pub fn is_node_scope(&self) -> bool {
        *self == NODE_SCOPE
    }
}

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_node_scope() {
            write!(f, "node")
        } else {
            write!(f, "{}", self.0)
        }
    }
}

/// Simulated device seconds a request accumulates, split by phase — the
/// latency-attribution ledger. Replaces the old scalar `sim_s` on the
/// live/parked/carried sequence state, so "where did this request's
/// simulated latency go" is answerable per request, not just per node:
///
/// - `prefill_s` — fresh prefill of uncached prompt positions;
/// - `decode_s` — productive decode rounds;
/// - `stall_s` — swap transfer tails the engine actually waited for
///   (the overhang past the concurrent round, plus swap-in restores);
/// - `replay_s` — recompute paid to faults and drop-preemptions: rescue
///   replay on a survivor, resume-recompute after an eviction.
///
/// The sum is the request's end-to-end simulated device latency
/// ([`PhaseLedger::device_s`] — what `GenResponse::simulated_device_s`
/// reports), and the per-phase split is what the Chrome-trace exporter
/// renders as the request's lifecycle slices.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseLedger {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub stall_s: f64,
    pub replay_s: f64,
}

impl PhaseLedger {
    /// End-to-end simulated device latency: the phase sum.
    pub fn device_s(&self) -> f64 {
        self.prefill_s + self.decode_s + self.stall_s + self.replay_s
    }

    /// Fold another ledger in (a rescue carries the dead node's phases).
    pub fn add(&mut self, other: &PhaseLedger) {
        self.prefill_s += other.prefill_s;
        self.decode_s += other.decode_s;
        self.stall_s += other.stall_s;
        self.replay_s += other.replay_s;
    }
}

/// Per-node / per-tenant latency-attribution rollup: wall queueing delay
/// plus the simulated phase ledger, summed over retired requests.
/// [`crate::coordinator::Metrics`] carries one and merges it fleet-wide.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Attribution {
    /// Wall-clock queueing delay, seconds (submit → admission).
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub stall_s: f64,
    pub replay_s: f64,
}

impl Attribution {
    /// Fold one retired request in.
    pub fn record(&mut self, queue_s: f64, ledger: &PhaseLedger) {
        self.queue_s += queue_s;
        self.prefill_s += ledger.prefill_s;
        self.decode_s += ledger.decode_s;
        self.stall_s += ledger.stall_s;
        self.replay_s += ledger.replay_s;
    }

    /// Fold another rollup in (fleet/tenant aggregation).
    pub fn merge(&mut self, other: &Attribution) {
        self.queue_s += other.queue_s;
        self.prefill_s += other.prefill_s;
        self.decode_s += other.decode_s;
        self.stall_s += other.stall_s;
        self.replay_s += other.replay_s;
    }

    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s + self.stall_s + self.replay_s
    }
}

/// What happened. Request-scoped kinds carry the request's [`TraceId`]
/// on their [`SpanEvent`]; node-scoped kinds (decode rounds, faults) use
/// [`NODE_SCOPE`].
#[derive(Clone, Debug, PartialEq)]
pub enum SpanKind {
    /// Entered the QoS admission queue (dispatch-stage journal).
    Queued,
    /// A rescue/retry re-entered the queue ahead of the backlog.
    Requeued,
    /// The aging promoter held new admissions for a parked sequence.
    Aged,
    /// Routed onto `node`'s bounded work queue.
    Dispatched { node: usize },
    /// The worker admitted it into its decode set; `cached_tokens`
    /// prompt positions were already resident (prefix hits).
    Admitted { cached_tokens: usize },
    /// Fresh prefill charged `sim_s` to the simulated clock.
    Prefill { sim_s: f64 },
    /// One continuous-batching decode round of `seqs` sequences
    /// (node-scoped; `sim_s` is the round's simulated duration).
    DecodeRound { seqs: usize, sim_s: f64 },
    /// Evicted under KV page pressure; `swapped` = pages parked in host
    /// RAM instead of dropped.
    Preempted { swapped: bool },
    /// Entered the fleet-shared park lot.
    Parked,
    /// A foreign idle card claimed this parked sequence off node `from`
    /// (live migration).
    Migrated { from: usize },
    /// KV pages moved device → host; `stall_s` is the transfer tail the
    /// round could not hide.
    SwapOut { bytes: u64, stall_s: f64 },
    /// KV pages restored host → device.
    SwapIn { bytes: u64, stall_s: f64 },
    /// Re-queued off dead node `from` with generated tokens carried.
    Rescued { from: usize },
    /// Carried tokens replayed / evicted prefill recomputed, `sim_s`
    /// charged as replay.
    Replayed { tokens: usize, sim_s: f64 },
    /// Served. `queue_s` (wall) + `ledger` (simulated phases) is the
    /// request's full latency story; the Chrome exporter reconstructs
    /// its lifecycle slices from this one event.
    Retired { tokens: usize, queue_s: f64, ledger: PhaseLedger },
    /// Terminal failure, with the error the client saw.
    Failed { error: String },
    /// Shed at the dispatch stage (energy budget, no healthy node, …).
    Shed { error: String },
    /// Wall-clock deadline passed before a card could serve it.
    DeadlineMiss,
    /// A fault fired on this node's round clock (node-scoped; `kind` is
    /// [`crate::faults::FaultKind::name`]).
    Fault { kind: &'static str },
}

impl SpanKind {
    /// Stable lowercase name — the `kind` field of every exported line.
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Queued => "queued",
            SpanKind::Requeued => "requeued",
            SpanKind::Aged => "aged",
            SpanKind::Dispatched { .. } => "dispatched",
            SpanKind::Admitted { .. } => "admitted",
            SpanKind::Prefill { .. } => "prefill",
            SpanKind::DecodeRound { .. } => "decode_round",
            SpanKind::Preempted { .. } => "preempted",
            SpanKind::Parked => "parked",
            SpanKind::Migrated { .. } => "migrated",
            SpanKind::SwapOut { .. } => "swap_out",
            SpanKind::SwapIn { .. } => "swap_in",
            SpanKind::Rescued { .. } => "rescued",
            SpanKind::Replayed { .. } => "replayed",
            SpanKind::Retired { .. } => "retired",
            SpanKind::Failed { .. } => "failed",
            SpanKind::Shed { .. } => "shed",
            SpanKind::DeadlineMiss => "deadline_miss",
            SpanKind::Fault { .. } => "fault",
        }
    }
}

/// One journal entry: a [`SpanKind`] at a (node, round, simulated-clock)
/// coordinate. `seq` is the per-ring sequence the journal assigned —
/// strictly increasing per node, so `(node, seq)` is a total order over
/// a node's history even after ring wraps drop old entries.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    pub seq: u64,
    pub node: usize,
    pub round: u64,
    /// The node's simulated clock at emission, seconds.
    pub sim_s: f64,
    pub trace: TraceId,
    pub kind: SpanKind,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_device_seconds_is_the_phase_sum() {
        let mut l = PhaseLedger {
            prefill_s: 0.1,
            decode_s: 0.2,
            stall_s: 0.025,
            replay_s: 0.075,
        };
        assert!((l.device_s() - 0.4).abs() < 1e-12);
        l.add(&PhaseLedger { decode_s: 0.6, ..PhaseLedger::default() });
        assert!((l.device_s() - 1.0).abs() < 1e-12);
        assert!((l.decode_s - 0.8).abs() < 1e-12);
        assert_eq!(PhaseLedger::default().device_s(), 0.0);
    }

    #[test]
    fn attribution_records_and_merges() {
        let mut a = Attribution::default();
        a.record(0.5, &PhaseLedger { prefill_s: 0.1, decode_s: 0.3, ..Default::default() });
        a.record(0.25, &PhaseLedger { replay_s: 0.05, stall_s: 0.1, ..Default::default() });
        assert!((a.queue_s - 0.75).abs() < 1e-12);
        assert!((a.prefill_s - 0.1).abs() < 1e-12);
        assert!((a.total_s() - 1.3).abs() < 1e-12);
        let mut b = Attribution::default();
        b.merge(&a);
        b.merge(&a);
        assert!((b.total_s() - 2.6).abs() < 1e-12);
        assert!((b.decode_s - 0.6).abs() < 1e-12);
    }

    #[test]
    fn trace_ids_format_and_node_scope_is_reserved() {
        assert_eq!(TraceId(7).to_string(), "7");
        assert_eq!(NODE_SCOPE.to_string(), "node");
        assert!(NODE_SCOPE.is_node_scope());
        assert!(!TraceId(0).is_node_scope());
    }

    #[test]
    fn span_kind_names_are_stable() {
        assert_eq!(SpanKind::Queued.name(), "queued");
        assert_eq!(SpanKind::Dispatched { node: 1 }.name(), "dispatched");
        assert_eq!(
            SpanKind::Retired { tokens: 4, queue_s: 0.0, ledger: PhaseLedger::default() }.name(),
            "retired"
        );
        assert_eq!(SpanKind::Fault { kind: "node_death" }.name(), "fault");
        assert_eq!(SpanKind::DeadlineMiss.name(), "deadline_miss");
    }
}
