//! Minimal JSON reader for `artifacts/goldens.json` (no serde in the
//! offline crate set — a small recursive-descent parser is all we need;
//! the goldens file is machine-written with known structure).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// JSON value (subset: everything the goldens file uses).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers → Vec<f32>.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_array()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    /// Array of numbers → Vec<i64>.
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_array()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as i64).collect())
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("bad object at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("bad array at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let start = self.pos;
                    while !matches!(self.peek(), Some(b'"' | b'\\') | None) {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Load and parse a goldens file.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

/// Read one usize field from an artifact directory's goldens `config`
/// block (e.g. `prefill_t`) — shared by the tests and benches that size
/// KV-page budgets to the artifact geometry.
pub fn config_usize(dir: &super::ArtifactDir, key: &str) -> Result<usize> {
    let goldens = load(dir.path("goldens.json"))?;
    match goldens.get("config").and_then(|c| c.get(key)).and_then(Json::as_usize) {
        Some(v) => Ok(v),
        None => bail!("goldens config missing {key}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        let j = parse(r#"{"a": 1.5, "b": [1, 2, 3], "c": {"d": "x\n", "e": true}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b").unwrap().as_i64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.get("c").unwrap().get("d"), Some(&Json::Str("x\n".into())));
        assert_eq!(j.get("c").unwrap().get("e"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parses_negative_and_exponent() {
        let j = parse("[-1.25e-3, 1E4, -7]").unwrap();
        let v = j.as_f32_vec().unwrap();
        assert_eq!(v, vec![-0.00125, 10000.0, -7.0]);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn roundtrips_the_goldens_shape() {
        let j = parse(r#"{"prompt": [1,2], "mixbench": {"x": [0.5], "max_divergence": 0.25}}"#)
            .unwrap();
        assert_eq!(
            j.get("mixbench").unwrap().get("max_divergence").unwrap().as_f64(),
            Some(0.25)
        );
    }
}
