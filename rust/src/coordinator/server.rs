//! The fleet serving engine: a shared admission queue feeding N per-card
//! continuous-batching workers.
//!
//! Life of a request: client → bounded queue → dispatch stage (the
//! [`Fleet`] router picks a card) → that node's worker joins the request
//! into its decode round as soon as a KV slot is free (vLLM-style
//! continuous batching — no stop-the-world batch windows), prefills it,
//! and interleaves decode steps per [`scheduler::plan_round_into`] until
//! the sequence hits its target → reply on the request's channel. Failures
//! are contained per request; a dropped reply receiver is a cancellation.
//!
//! Every node owns its own [`ModelRuntime`], [`KvSlots`] sized to its
//! card's VRAM, [`Metrics`], and a simulated device-time/energy overlay
//! calibrated per card (any mix of registry [`DeviceSpec`]s), so a
//! heterogeneous fleet — a 170HX next to a 90HX — reports fleet-wide
//! tokens/s and tokens/joule.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::device::{registry, DeviceSpec};
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::{BenchResult, LlamaBench};
use crate::llm::model::ModelDesc;
use crate::llm::quant;
use crate::runtime::{ArtifactDir, DecodeState, ModelRuntime};

use super::batcher::BatchPolicy;
use super::kv::KvSlots;
use super::metrics::{FleetMetrics, Metrics};
use super::request::{GenRequest, GenResponse};
use super::router::{Fleet, Node, RoutePolicy};
use super::scheduler::{plan_admission, plan_round_into, SeqView, StepPolicy};

/// One card of the serving fleet: the simulated device identity and the
/// fmad policy its deployment would run.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    pub device: DeviceSpec,
    pub fmad: FmadPolicy,
}

impl NodeConfig {
    pub fn new(device: DeviceSpec, fmad: FmadPolicy) -> Self {
        NodeConfig { device, fmad }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bound of **each** engine queue: the shared dispatch queue and every
    /// node's own queue (so a fleet buffers up to `(1 + nodes) ×
    /// queue_depth` requests, plus one in the dispatcher's hand, before
    /// `submit` sheds load).
    pub queue_depth: usize,
    /// Per-node admission policy (concurrency cap + cold-start gather).
    pub batch: BatchPolicy,
    pub step_policy: StepPolicy,
    /// fmad policy of the default single-node deployment (and of nodes
    /// added via the CLI); explicit [`NodeConfig`]s carry their own.
    pub fmad: FmadPolicy,
    /// Dispatch-stage routing policy across the fleet.
    pub route: RoutePolicy,
    /// The fleet. Empty = one CMP 170HX (the single-card path, unchanged
    /// in behaviour and per-request results).
    pub nodes: Vec<NodeConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 64,
            batch: BatchPolicy::default(),
            step_policy: StepPolicy::RoundRobin,
            fmad: FmadPolicy::Decomposed,
            route: RoutePolicy::WeightedThroughput,
            nodes: Vec::new(),
        }
    }
}

/// Client handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    tx: Option<SyncSender<GenRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    node_names: Vec<&'static str>,
    node_metrics: Vec<Arc<Mutex<Metrics>>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Simulated per-token device time and power for one node's overlay.
#[derive(Clone, Copy, Debug)]
struct Overlay {
    prefill_s_per_token: f64,
    decode_s_per_token: f64,
    /// Prefill is compute-saturated, so the DVFS governor pins the board at
    /// its envelope — [`crate::power::PowerModel::board_power`] clips
    /// saturated activity to TDP, which is what we charge per prefill
    /// second.
    prefill_w: f64,
    /// Decode power from the §4.4 calibrated residency model.
    decode_w: f64,
}

impl Overlay {
    /// Overlay for one node serving the paper's Qwen2.5-1.5B in q8_0 — the
    /// workload §6.2 recommends — from its calibrated bench row.
    fn from_row(row: &BenchResult, dev: &DeviceSpec) -> Overlay {
        Overlay {
            prefill_s_per_token: 1.0 / row.prefill_tps,
            decode_s_per_token: 1.0 / row.decode_tps,
            prefill_w: dev.tdp_w,
            decode_w: row.decode_power_w,
        }
    }
}

/// The serving engine.
pub struct Server;

impl Server {
    /// Start the fleet over an artifact directory: one runtime-owning
    /// worker per node plus the dispatch stage. Compilation happens on the
    /// worker threads; `start` returns once every node is live (or the
    /// first error is known).
    pub fn start(artifacts: ArtifactDir, config: ServerConfig) -> Result<ServerHandle> {
        let model = ModelDesc::qwen25_15b();
        let nodes: Vec<NodeConfig> = if config.nodes.is_empty() {
            vec![NodeConfig::new(registry::cmp170hx(), config.fmad)]
        } else {
            config.nodes.clone()
        };

        // One calibrated bench row per node: overlay rates, routing weight,
        // and decode power all come from a single batched sweep.
        let bench = LlamaBench { model, ..Default::default() };
        let cells: Vec<(DeviceSpec, FmadPolicy)> =
            nodes.iter().map(|n| (n.device.clone(), n.fmad)).collect();
        let rows = bench.run_nodes(&cells, &quant::Q8_0);

        let fleet = Arc::new(Mutex::new(Fleet::new(
            nodes
                .iter()
                .zip(&rows)
                .map(|(n, r)| Node {
                    name: n.device.name,
                    weight: r.decode_tps,
                    outstanding: 0,
                    assigned: 0,
                })
                .collect(),
            config.route,
        )));

        let queue_depth = config.queue_depth.max(1);
        let weights_bytes = model.weight_bytes(&quant::Q8_0);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(nodes.len());
        let mut worker_txs: Vec<SyncSender<GenRequest>> = Vec::with_capacity(nodes.len());
        let mut workers = Vec::with_capacity(nodes.len());
        let mut node_metrics = Vec::with_capacity(nodes.len());
        let node_names: Vec<&'static str> = nodes.iter().map(|n| n.device.name).collect();

        for (i, (node, row)) in nodes.iter().zip(&rows).enumerate() {
            let (wtx, wrx) = sync_channel::<GenRequest>(queue_depth);
            worker_txs.push(wtx);
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            node_metrics.push(Arc::clone(&metrics));

            let overlay = Overlay::from_row(row, &node.device);
            let vram_bytes = node.device.mem.capacity_bytes;
            let slots_per_node = config.batch.concurrency();
            let artifacts = artifacts.clone();
            let ready = ready_tx.clone();
            let fleet = Arc::clone(&fleet);
            let policy = config.batch;
            let step_policy = config.step_policy;

            let worker = std::thread::Builder::new()
                .name(format!("cmphx-node{i}"))
                .spawn(move || {
                    let runtime = match ModelRuntime::load(&artifacts) {
                        Ok(rt) => rt,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    // KV slots sized against this node's own VRAM: weights
                    // plus per-slot KV of the serving model must fit the
                    // card (the binding 8 GB ceiling for the 170HX).
                    let slots = match KvSlots::new(
                        slots_per_node,
                        model.kv_bytes_per_pos() * runtime.config.max_ctx as u64,
                        vram_bytes,
                        weights_bytes,
                    ) {
                        Ok(s) => s,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    let _ = ready.send(Ok(()));
                    worker_loop(NodeWorker {
                        node: i,
                        runtime,
                        rx: wrx,
                        policy,
                        step_policy,
                        overlay,
                        slots,
                        metrics,
                        fleet,
                    });
                })?;
            workers.push(worker);
        }
        drop(ready_tx);
        for _ in 0..nodes.len() {
            ready_rx.recv()??;
        }

        // Dispatch stage: the Fleet's routing policy IS the fan-out.
        let (tx, rx) = sync_channel::<GenRequest>(queue_depth);
        let fleet_d = Arc::clone(&fleet);
        let metrics_d: Vec<Arc<Mutex<Metrics>>> =
            node_metrics.iter().map(Arc::clone).collect();
        let dispatcher = std::thread::Builder::new()
            .name("cmphx-dispatch".into())
            .spawn(move || {
                while let Ok(req) = rx.recv() {
                    let idx = fleet_d.lock().unwrap().route();
                    if let Err(SendError(req)) = worker_txs[idx].send(req) {
                        // Worker gone (it panicked or was torn down): fail
                        // the request instead of wedging the queue.
                        fleet_d.lock().unwrap().complete(idx);
                        let queue_s = req.enqueued.elapsed().as_secs_f64();
                        metrics_d[idx].lock().unwrap().record_response(queue_s, 0, false);
                        let _ = req.reply.send(GenResponse {
                            id: req.id,
                            tokens: vec![],
                            error: Some("node worker unavailable".into()),
                            queue_s,
                            prefill_s: 0.0,
                            decode_s: 0.0,
                            simulated_device_s: 0.0,
                            node: idx,
                        });
                    }
                }
                // Dropping worker_txs here closes every node queue; the
                // workers drain what was already routed, then exit.
            })?;

        Ok(ServerHandle {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            node_names,
            node_metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }
}

impl ServerHandle {
    /// Submit a generation request; returns the response receiver. Errors
    /// when the queue is full (backpressure) or the server is stopped.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_tokens: usize,
    ) -> Result<Receiver<GenResponse>> {
        let (reply, rx) = std::sync::mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = GenRequest {
            id,
            prompt,
            max_tokens,
            reply,
            enqueued: Instant::now(),
        };
        let tx = self.tx.as_ref().ok_or_else(|| anyhow::anyhow!("server stopped"))?;
        match tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Fleet-wide metrics snapshot (all nodes merged).
    pub fn metrics(&self) -> Metrics {
        self.fleet_metrics().total()
    }

    /// Per-node metrics snapshot.
    pub fn fleet_metrics(&self) -> FleetMetrics {
        FleetMetrics {
            nodes: self
                .node_names
                .iter()
                .zip(&self.node_metrics)
                .map(|(name, m)| (*name, m.lock().unwrap().clone()))
                .collect(),
        }
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Stop accepting requests, drain, and join the fleet.
    pub fn shutdown(mut self) -> Metrics {
        self.stop();
        self.metrics()
    }

    /// Like [`ServerHandle::shutdown`], keeping per-node attribution.
    pub fn shutdown_fleet(mut self) -> FleetMetrics {
        self.stop();
        self.fleet_metrics()
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Everything one node's continuous-batching loop owns.
struct NodeWorker {
    node: usize,
    runtime: ModelRuntime,
    rx: Receiver<GenRequest>,
    policy: BatchPolicy,
    step_policy: StepPolicy,
    overlay: Overlay,
    slots: KvSlots,
    metrics: Arc<Mutex<Metrics>>,
    fleet: Arc<Mutex<Fleet>>,
}

/// One in-flight sequence.
struct Live {
    req: GenRequest,
    state: DecodeState,
    slot: usize,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    sim_s: f64,
    sim_j: f64,
    failed: Option<String>,
    decode_started: Instant,
}

impl Live {
    fn target(&self) -> usize {
        if self.failed.is_some() {
            self.tokens.len()
        } else {
            self.req.max_tokens.max(1)
        }
    }

    fn done(&self) -> bool {
        self.tokens.len() >= self.target()
    }
}

fn worker_loop(mut w: NodeWorker) {
    let mut live: Vec<Live> = Vec::new();
    // Round-planning buffers reused across the engine's lifetime: planning
    // a round allocates nothing after the first.
    let mut views: Vec<SeqView> = Vec::new();
    let mut plan: Vec<usize> = Vec::new();
    let mut open = true;

    while open || !live.is_empty() {
        // --- admission (slot-join): fill free slots, never stall decode ---
        let mut want = plan_admission(&w.policy, live.len(), w.slots.free_slots());
        if open && want > 0 {
            if live.is_empty() {
                // Idle engine: block for the first arrival, then gather up
                // to `max_wait` of company for the cold-start round.
                match w.rx.recv() {
                    Ok(req) => {
                        if admit(&mut w, req, &mut live) {
                            want -= 1;
                        }
                        let deadline = Instant::now() + w.policy.max_wait;
                        while want > 0 {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            match w.rx.recv_timeout(deadline - now) {
                                Ok(req) => {
                                    if admit(&mut w, req, &mut live) {
                                        want -= 1;
                                    }
                                }
                                Err(RecvTimeoutError::Timeout) => break,
                                Err(RecvTimeoutError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                    }
                    Err(_) => open = false,
                }
            } else {
                // Busy engine: non-blocking joins — the continuous part.
                while want > 0 {
                    match w.rx.try_recv() {
                        Ok(req) => {
                            if admit(&mut w, req, &mut live) {
                                want -= 1;
                            }
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        if live.is_empty() {
            continue;
        }

        // --- one decode round across the in-flight set ---
        views.clear();
        views.extend(live.iter().enumerate().map(|(i, l)| SeqView {
            seq: i,
            generated: l.tokens.len(),
            target: l.target(),
        }));
        plan_round_into(w.step_policy, &views, &mut plan);
        if !plan.is_empty() {
            w.metrics.lock().unwrap().record_batch(plan.len());
            for &idx in &plan {
                let l = &mut live[idx];
                let token = *l.tokens.last().unwrap();
                match w.runtime.decode(&mut l.state, token) {
                    Ok(()) => {
                        l.tokens.push(l.state.argmax());
                        l.sim_s += w.overlay.decode_s_per_token;
                        l.sim_j += w.overlay.decode_s_per_token * w.overlay.decode_w;
                    }
                    Err(e) => l.failed = Some(format!("decode failed: {e}")),
                }
            }
        }

        // --- retire finished sequences; their slots free for the next
        //     round's admissions ---
        let mut i = 0;
        while i < live.len() {
            if !live[i].done() {
                i += 1;
                continue;
            }
            let l = live.swap_remove(i);
            retire(&mut w, l);
        }
    }
}

/// Admit one routed request: window checks, KV slot, prefill. Returns true
/// when the request joined the in-flight set.
fn admit(w: &mut NodeWorker, req: GenRequest, live: &mut Vec<Live>) -> bool {
    let cfg = w.runtime.config;
    let queue_s = req.enqueued.elapsed().as_secs_f64();
    let budget = cfg.max_ctx - cfg.prefill_t;
    if req.prompt.len() > cfg.prefill_t || req.max_tokens > budget {
        let msg = format!(
            "request exceeds window (prompt {} > {} or tokens {} > {})",
            req.prompt.len(),
            cfg.prefill_t,
            req.max_tokens,
            budget
        );
        reject(w, &req, msg, queue_s);
        return false;
    }
    let Some(slot) = w.slots.acquire() else {
        reject(w, &req, "no KV slot (overload)".into(), queue_s);
        return false;
    };
    let t0 = Instant::now();
    match w.runtime.prefill_padded(&req.prompt) {
        Ok(state) => {
            let prefill_s = t0.elapsed().as_secs_f64();
            let sim_s = w.overlay.prefill_s_per_token * cfg.prefill_t as f64;
            let sim_j = sim_s * w.overlay.prefill_w;
            let first = state.argmax();
            live.push(Live {
                req,
                state,
                slot,
                tokens: vec![first],
                queue_s,
                prefill_s,
                sim_s,
                sim_j,
                failed: None,
                decode_started: Instant::now(),
            });
            true
        }
        Err(e) => {
            w.slots
                .release(slot)
                .expect("releasing the just-acquired slot");
            reject(w, &req, format!("prefill failed: {e}"), queue_s);
            false
        }
    }
}

/// Retire one finished (or failed) sequence: release its slot, account
/// metrics, tell the router, reply.
fn retire(w: &mut NodeWorker, l: Live) {
    w.slots.release(l.slot).expect("slot accounting");
    let decode_s = l.decode_started.elapsed().as_secs_f64();
    let ok = l.failed.is_none();
    let resp = GenResponse {
        id: l.req.id,
        tokens: l.tokens,
        error: l.failed,
        queue_s: l.queue_s,
        prefill_s: l.prefill_s,
        decode_s,
        simulated_device_s: l.sim_s,
        node: w.node,
    };
    {
        let mut m = w.metrics.lock().unwrap();
        m.wall_prefill_s += l.prefill_s;
        m.wall_decode_s += decode_s;
        m.simulated_device_s += l.sim_s;
        m.simulated_energy_j += l.sim_j;
        m.record_response(resp.latency_s(), resp.tokens.len(), ok);
    }
    w.fleet.lock().unwrap().complete(w.node);
    // dropped receiver = cancelled; ignore send failure
    let _ = l.req.reply.send(resp);
}

/// Reply with a terminal error before the request ever held a slot.
fn reject(w: &mut NodeWorker, req: &GenRequest, error: String, queue_s: f64) {
    w.metrics.lock().unwrap().record_response(queue_s, 0, false);
    w.fleet.lock().unwrap().complete(w.node);
    let _ = req.reply.send(GenResponse {
        id: req.id,
        tokens: vec![],
        error: Some(error),
        queue_s,
        prefill_s: 0.0,
        decode_s: 0.0,
        simulated_device_s: 0.0,
        node: w.node,
    });
}
