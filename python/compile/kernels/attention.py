"""Pallas GQA decode attention — one new token against the KV cache.

CUDA decode attention parallelizes heads across thread-blocks and streams
the KV cache from HBM. TPU rethink: grid over **KV heads** (not query
heads) — each program holds its KV head's cache panel in VMEM once and
serves the whole query-head *group* against it (GQA's point is that the
group shares the panel; gridding by query head would re-stream it
`group`× from HBM). Masked softmax uses the running-max trick; the cache
layout ``[T, KV, D]`` matches the L2 model's arrays so the kernel lowers
into the decode HLO unchanged.

Perf note (EXPERIMENTS.md §Perf): the original version gridded over the 8
query heads; regrouping by the 2 KV heads cut grid programs 4× and
measurably shrank the decode executable's op count.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    # q [group, D] — this program's query-head group
    # k/v [T, 1, D] — the group's shared KV head panel
    q = q_ref[...]
    k = k_ref[:, 0, :]  # [T, D]
    v = v_ref[:, 0, :]
    length = len_ref[0]
    t = k.shape[0]
    d = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [group, T]
    mask = (jnp.arange(t) < length)[None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=1, keepdims=True)
    w = jnp.exp(scores - m)
    w = jnp.where(mask, w, 0.0)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    o_ref[...] = jnp.dot(w, v, preferred_element_type=jnp.float32)  # [group, D]


@functools.partial(jax.jit, static_argnames=("kv_heads",))
def gqa_decode_attention(q, k_cache, v_cache, length, *, kv_heads: int):
    """q [H, D], k/v_cache [T, KV, D], length scalar i32 -> [H, D].

    Query heads must be grouped by KV head (standard GQA layout: heads
    ``[g*group, (g+1)*group)`` share KV head ``g``).
    """
    h, d = q.shape
    t, kv, _ = k_cache.shape
    assert kv == kv_heads and h % kv == 0
    group = h // kv
    length = jnp.asarray(length, jnp.int32).reshape(1)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        grid=(kv,),
        in_specs=[
            pl.BlockSpec((group, d), lambda g: (g, 0)),
            pl.BlockSpec((t, 1, d), lambda g: (0, g, 0)),
            pl.BlockSpec((t, 1, d), lambda g: (0, g, 0)),
            pl.BlockSpec((1,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((group, d), lambda g: (g, 0)),
        interpret=True,
    )(q, k_cache, v_cache, length)
