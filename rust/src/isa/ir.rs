//! Structured kernel IR.
//!
//! A [`Kernel`] is what a benchmark workload hands to the simulator: a
//! per-thread body of counted ops and loops, a launch geometry, and a global
//! memory traffic descriptor. The IR is deliberately small — just enough
//! structure for the `-fmad=false` pass to be a *real* rewrite (it must
//! recurse through loops and respect the compiled-library boundary) rather
//! than a scalar fudge factor.

use super::class::InstClass;

/// One arithmetic/memory operation, executed `count` times per thread at the
/// IR position it appears in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Op {
    pub class: InstClass,
    pub count: u64,
}

impl Op {
    pub fn new(class: InstClass, count: u64) -> Self {
        Op { class, count }
    }
}

/// Statement: a counted op or a counted loop over a sub-body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Stmt {
    Op(Op),
    /// `trips` executions of `body` per thread.
    Loop { trips: u64, body: Vec<Stmt> },
}

impl Stmt {
    pub fn op(class: InstClass, count: u64) -> Stmt {
        Stmt::Op(Op::new(class, count))
    }

    pub fn looped(trips: u64, body: Vec<Stmt>) -> Stmt {
        Stmt::Loop { trips, body }
    }
}

/// Where the kernel's machine code comes from. The `-fmad=false` compiler
/// flag only affects code the user compiles; prebuilt libraries (cuBLAS,
/// cuDNN) ship fixed SASS. This boundary is the mechanism behind the paper's
/// observation that llama.cpp f16/f32 models (cuBLAS GEMM path) gain nothing
/// from disabling FMA while quantized models (JIT-compiled MMQ kernels) gain
/// up to 2.3×.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSource {
    /// Compiled from source by the user's toolchain — fmad policy applies.
    Jit,
    /// Shipped as a prebuilt binary library — fmad policy does NOT apply.
    Lib,
}

/// Global-memory access pattern; selects the achieved-bandwidth curve in
/// [`crate::memhier`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemPattern {
    /// Fully coalesced, 128B-aligned warp transactions.
    Coalesced,
    /// Deliberately misaligned (OpenCL-Benchmark's "misaligned" case).
    Misaligned,
    /// Strided gather (quantized-GEMM weight walks, attention KV reads).
    Strided,
}

/// Global memory traffic of one kernel launch (whole grid, not per thread).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Traffic {
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub pattern: MemPattern,
    /// Fraction of reads served by L2 (working-set reuse); 0.0 = all HBM.
    pub l2_hit_rate: f64,
}

impl Traffic {
    pub fn none() -> Self {
        Traffic {
            read_bytes: 0,
            write_bytes: 0,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: 0.0,
        }
    }

    pub fn coalesced(read_bytes: u64, write_bytes: u64) -> Self {
        Traffic {
            read_bytes,
            write_bytes,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: 0.0,
        }
    }

    /// Total bytes that reach the memory system.
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    /// Bytes that miss L2 and hit HBM.
    pub fn hbm_bytes(&self) -> f64 {
        self.read_bytes as f64 * (1.0 - self.l2_hit_rate) + self.write_bytes as f64
    }
}

/// A launchable kernel: geometry + per-thread body + traffic descriptor.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    /// Total threads in the grid (flattened).
    pub threads: u64,
    /// Threads per block (occupancy input).
    pub block: u32,
    /// Per-thread instruction body.
    pub body: Vec<Stmt>,
    /// Whole-grid global memory traffic.
    pub traffic: Traffic,
    pub source: KernelSource,
}

impl Kernel {
    pub fn new(name: impl Into<String>, threads: u64, block: u32) -> Self {
        Kernel {
            name: name.into(),
            threads,
            block,
            body: Vec::new(),
            traffic: Traffic::none(),
            source: KernelSource::Jit,
        }
    }

    pub fn with_body(mut self, body: Vec<Stmt>) -> Self {
        self.body = body;
        self
    }

    pub fn with_traffic(mut self, traffic: Traffic) -> Self {
        self.traffic = traffic;
        self
    }

    pub fn with_source(mut self, source: KernelSource) -> Self {
        self.source = source;
        self
    }

    /// Per-thread dynamic instruction count (loops expanded).
    pub fn dynamic_insts_per_thread(&self) -> u64 {
        fn walk(stmts: &[Stmt]) -> u64 {
            stmts
                .iter()
                .map(|s| match s {
                    Stmt::Op(op) => op.count,
                    Stmt::Loop { trips, body } => trips * walk(body),
                })
                .sum()
        }
        walk(&self.body)
    }

    /// Blocks in the grid.
    pub fn blocks(&self) -> u64 {
        self.threads.div_ceil(self.block as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;

    fn sample_kernel() -> Kernel {
        Kernel::new("k", 1024, 256).with_body(vec![
            Stmt::op(Ldg, 2),
            Stmt::looped(10, vec![Stmt::op(Ffma, 4), Stmt::looped(2, vec![Stmt::op(Fadd, 1)])]),
            Stmt::op(Stg, 1),
        ])
    }

    #[test]
    fn dynamic_count_expands_nested_loops() {
        let k = sample_kernel();
        // 2 + 10*(4 + 2*1) + 1 = 63
        assert_eq!(k.dynamic_insts_per_thread(), 63);
    }

    #[test]
    fn blocks_round_up() {
        let k = Kernel::new("k", 1000, 256);
        assert_eq!(k.blocks(), 4);
        let k = Kernel::new("k", 1024, 256);
        assert_eq!(k.blocks(), 4);
    }

    #[test]
    fn traffic_accounting() {
        let mut t = Traffic::coalesced(1000, 500);
        assert_eq!(t.total_bytes(), 1500);
        assert_eq!(t.hbm_bytes(), 1500.0);
        t.l2_hit_rate = 0.5;
        assert_eq!(t.hbm_bytes(), 1000.0);
    }

    #[test]
    fn default_source_is_jit() {
        assert_eq!(sample_kernel().source, KernelSource::Jit);
    }
}
