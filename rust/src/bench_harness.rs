//! Mini-criterion: a deterministic benchmark harness for `harness = false`
//! bench targets (the image ships no `criterion` crate).
//!
//! Two modes:
//! - [`time_fn`] — wall-clock a closure with warmup + N samples, reporting
//!   mean/σ/min (used by the L3 perf pass and the e2e serve bench);
//! - [`Table`]/[`Row`] — the figure emitters: every paper graph/table bench
//!   prints one of these, with a `paper` column next to `measured` so the
//!   regenerated figure is directly comparable.

use std::time::Instant;

/// Statistics from [`time_fn`].
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub samples: u32,
}

impl Stats {
    /// Throughput for `units` of work per invocation.
    pub fn per_sec(&self, units: f64) -> f64 {
        units / self.mean_s
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `samples` timed runs.
pub fn time_fn<F: FnMut()>(warmup: u32, samples: u32, mut f: F) -> Stats {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / samples as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / samples as f64;
    Stats {
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        samples,
    }
}

/// One row of a figure table.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub measured: f64,
    /// Paper-reported value if one exists for this row.
    pub paper: Option<f64>,
    pub note: String,
}

impl Row {
    pub fn new(label: impl Into<String>, measured: f64) -> Self {
        Row {
            label: label.into(),
            measured,
            paper: None,
            note: String::new(),
        }
    }

    pub fn paper(mut self, v: f64) -> Self {
        self.paper = Some(v);
        self
    }

    pub fn note(mut self, n: impl Into<String>) -> Self {
        self.note = n.into();
        self
    }

    /// Relative deviation from the paper value, if present.
    pub fn deviation(&self) -> Option<f64> {
        self.paper.map(|p| (self.measured - p) / p)
    }
}

/// A printable figure reproduction.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub unit: &'static str,
    pub rows: Vec<Row>,
}

impl Table {
    pub fn new(title: impl Into<String>, unit: &'static str) -> Self {
        Table {
            title: title.into(),
            unit,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Render as an aligned text table with a deviation column.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {} [{}] ==\n", self.title, self.unit));
        let w = self
            .rows
            .iter()
            .map(|r| r.label.len())
            .max()
            .unwrap_or(8)
            .max(8);
        out.push_str(&format!(
            "{:<w$}  {:>12}  {:>12}  {:>8}  note\n",
            "case", "measured", "paper", "dev",
        ));
        for r in &self.rows {
            let paper = r
                .paper
                .map(|p| format!("{p:>12.4}"))
                .unwrap_or_else(|| format!("{:>12}", "-"));
            let dev = r
                .deviation()
                .map(|d| format!("{:>+7.1}%", d * 100.0))
                .unwrap_or_else(|| format!("{:>8}", "-"));
            out.push_str(&format!(
                "{:<w$}  {:>12.4}  {}  {}  {}\n",
                r.label, r.measured, paper, dev, r.note,
            ));
        }
        out
    }

    /// Render as CSV (for EXPERIMENTS.md extraction).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("case,measured,paper,unit\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{},{}\n",
                r.label,
                r.measured,
                r.paper.map(|p| p.to_string()).unwrap_or_default(),
                self.unit,
            ));
        }
        out
    }

    /// Largest absolute relative deviation across rows that have paper
    /// values (figure-level reproduction check).
    pub fn worst_deviation(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter_map(|r| r.deviation())
            .map(f64::abs)
            .fold(None, |acc, d| Some(acc.map_or(d, |a: f64| a.max(d))))
    }
}

/// Splice `"key": <block>` into the benchmark result file at `path`
/// (read-modify-write), replacing the existing object value for `key` or
/// appending the key before the final brace, and leaving every other
/// bench's row untouched. BENCH_sim_throughput.json is shared by several
/// bench targets; wholesale rewrites made each row silently depend on
/// every other bench rerunning — row-owned upserts are the fix.
pub fn upsert_bench_row(path: &std::path::Path, key: &str, block: &str) {
    let text = std::fs::read_to_string(path).unwrap_or_else(|_| "{\n}\n".to_string());
    let updated = match try_upsert_json_block(&text, key, block) {
        Some(u) => u,
        None => {
            // Corrupt result file (truncated write, merge damage): park
            // the evidence in a .bak and rewrite fresh, instead of
            // panicking away the bench run that just finished measuring.
            let bak = path.with_extension("json.bak");
            match std::fs::write(&bak, &text) {
                Ok(()) => eprintln!(
                    "warning: {} is not a JSON object; quarantined to {} and rewriting",
                    path.display(),
                    bak.display()
                ),
                Err(e) => eprintln!(
                    "warning: {} is not a JSON object and could not be quarantined \
                     ({e}); rewriting",
                    path.display()
                ),
            }
            try_upsert_json_block("{\n}\n", key, block)
                .expect("a fresh empty object always splices")
        }
    };
    if let Err(e) = std::fs::write(path, updated) {
        eprintln!("warning: could not record {key} in {}: {e}", path.display());
    } else {
        println!("recorded {key} in {}", path.display());
    }
}

/// Pure splice behind [`upsert_bench_row`]: replace `key`'s brace-balanced
/// object value in `text`, or append `"key": block` before the final
/// closing brace when the key is absent. `block` must be a JSON object.
/// Returns `None` when `text` is not spliceable — the key's value is not
/// an object, its braces never balance, or there is no object to extend.
pub fn try_upsert_json_block(text: &str, key: &str, block: &str) -> Option<String> {
    let needle = format!("\"{key}\":");
    if let Some(start) = text.find(&needle) {
        // replace the existing object value (brace-balanced span)
        let vstart = start + needle.len();
        let obrace = vstart + text[vstart..].find('{')?;
        let mut depth = 0usize;
        let mut end = 0usize;
        for (i, c) in text[obrace..].char_indices() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = obrace + i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        if end == 0 {
            return None; // the value's braces never balance (truncated file)
        }
        Some(format!("{} {block}{}", &text[..vstart], &text[end..]))
    } else {
        let last = text.rfind('}')?;
        let body = text[..last].trim_end();
        let sep = if body.ends_with('{') { "" } else { "," };
        Some(format!("{body}{sep}\n  \"{key}\": {block}\n}}\n"))
    }
}

/// Panicking wrapper over [`try_upsert_json_block`] for callers that know
/// their input is well-formed (tests, fresh seeds).
pub fn upsert_json_block(text: &str, key: &str, block: &str) -> String {
    try_upsert_json_block(text, key, block).expect("well-formed bench result JSON")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_reports_sane_stats() {
        let s = time_fn(1, 8, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.samples, 8);
        assert!(s.mean_s >= s.min_s);
        assert!(s.mean_s > 0.0);
    }

    #[test]
    fn per_sec_inverts_mean() {
        let s = Stats {
            mean_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
            samples: 1,
        };
        assert_eq!(s.per_sec(100.0), 200.0);
    }

    #[test]
    fn row_deviation() {
        let r = Row::new("x", 110.0).paper(100.0);
        assert!((r.deviation().unwrap() - 0.1).abs() < 1e-12);
        assert!(Row::new("y", 1.0).deviation().is_none());
    }

    #[test]
    fn upsert_replaces_only_its_own_row() {
        let text = "{\n  \"a\": { \"x\": 1 },\n  \"b\": { \"nested\": { \"y\": 2 } },\n  \
                    \"note\": \"keep me\"\n}\n";
        // replacing a row with nested braces leaves the others intact
        let out = upsert_json_block(text, "b", "{ \"y\": 3 }");
        assert!(out.contains("\"b\": { \"y\": 3 }"), "{out}");
        assert!(out.contains("\"a\": { \"x\": 1 }"), "{out}");
        assert!(out.contains("\"note\": \"keep me\""), "{out}");
        assert!(!out.contains("nested"), "{out}");
        // idempotent: upserting the same block changes nothing more
        assert_eq!(upsert_json_block(&out, "b", "{ \"y\": 3 }"), out);
    }

    #[test]
    fn upsert_appends_missing_rows_and_seeds_empty_files() {
        let text = "{\n  \"a\": { \"x\": 1 }\n}\n";
        let out = upsert_json_block(text, "c", "{ \"z\": 9 }");
        assert!(out.contains("\"a\": { \"x\": 1 }"), "{out}");
        assert!(out.contains("\"c\": { \"z\": 9 }"), "{out}");
        assert!(out.trim_end().ends_with('}'), "{out}");
        // appending twice in sequence keeps both rows
        let out2 = upsert_json_block(&out, "d", "{ \"w\": 0 }");
        assert!(out2.contains("\"c\": { \"z\": 9 }") && out2.contains("\"d\": { \"w\": 0 }"));
        // a missing/empty file seeds a fresh object
        let seeded = upsert_json_block("{\n}\n", "only", "{ \"v\": 1 }");
        assert!(seeded.contains("\"only\": { \"v\": 1 }"), "{seeded}");
        assert!(!seeded.contains(",\n  \"only\""), "no stray comma after {{: {seeded}");
    }

    #[test]
    fn try_upsert_refuses_unspliceable_text() {
        // no object to extend at all
        assert!(try_upsert_json_block("", "k", "{ \"v\": 1 }").is_none());
        assert!(try_upsert_json_block("not json", "k", "{ \"v\": 1 }").is_none());
        // key present but its value is not an object
        assert!(try_upsert_json_block("{ \"k\": 12 }", "k", "{ \"v\": 1 }").is_none());
        // key's object value never closes (truncated write)
        assert!(try_upsert_json_block("{ \"k\": { \"x\": 1 ", "k", "{ \"v\": 1 }").is_none());
    }

    #[test]
    fn corrupt_result_files_are_quarantined_not_fatal() {
        let dir = std::env::temp_dir().join(format!(
            "cmphx-bench-quarantine-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_corrupt.json");
        std::fs::write(&path, "{ \"serve\": truncated-garbage").unwrap();
        // must not panic; must rewrite the file with the fresh row
        upsert_bench_row(&path, "serve", "{ \"tps\": 1 }");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"serve\": { \"tps\": 1 }"), "{text}");
        // the original bytes survive in the .bak for forensics
        let bak = std::fs::read_to_string(path.with_extension("json.bak")).unwrap();
        assert!(bak.contains("truncated-garbage"), "{bak}");
        // the rewritten file is spliceable again
        upsert_bench_row(&path, "other", "{ \"x\": 2 }");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"serve\": { \"tps\": 1 }"), "{text}");
        assert!(text.contains("\"other\": { \"x\": 2 }"), "{text}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_renders_all_rows_and_tracks_worst() {
        let mut t = Table::new("demo", "TFLOPS");
        t.push(Row::new("a", 1.0).paper(1.0));
        t.push(Row::new("b", 2.2).paper(2.0).note("hot"));
        let s = t.render();
        assert!(s.contains("demo") && s.contains("hot"));
        assert!((t.worst_deviation().unwrap() - 0.1).abs() < 1e-9);
        assert!(t.to_csv().lines().count() == 3);
    }
}
