"""Layer-2: tiny-Qwen — the Qwen2.5 architecture family at laptop scale.

Matches §4.1's architecture list: RoPE, SwiGLU, RMSNorm, attention QKV
bias, GQA, tied embeddings. The FFN matmuls run through the L1 Pallas
``qmatmul`` kernel on q8_0-quantized weights (the paper's quantized-model
path); decode attention runs through the L1 ``gqa_decode_attention``
kernel. Everything lowers into the same HLO the Rust runtime executes.

Pure-functional: params and caches are explicit pytrees. ``prefill``
consumes a prompt and builds the KV cache; ``decode_step`` extends it one
token. python/tests asserts prefill ≡ sequential decode.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import gqa_decode_attention
from .kernels.qmatmul import qmatmul_padded
from .kernels.ref import quantize_q8


@dataclass(frozen=True)
class Config:
    """tiny-qwen (mirrors rust's ModelDesc::tiny_qwen())."""

    vocab: int = 512
    hidden: int = 256
    layers: int = 4
    q_heads: int = 8
    kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 704
    max_ctx: int = 64
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6


def init_params(cfg: Config, seed: int = 0):
    """Random-but-deterministic parameters; FFN weights stored q8_0."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 8 + 16 * cfg.layers))

    def dense(k, shape, scale=None):
        scale = scale or (1.0 / jnp.sqrt(jnp.float32(shape[0])))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    params = {
        "embed": dense(next(keys), (cfg.vocab, cfg.hidden), 0.02),
        "final_norm": jnp.ones((cfg.hidden,), jnp.float32),
        "layers": [],
    }
    qdim = cfg.q_heads * cfg.head_dim
    kvdim = cfg.kv_heads * cfg.head_dim
    for _ in range(cfg.layers):
        layer = {
            "attn_norm": jnp.ones((cfg.hidden,), jnp.float32),
            "ffn_norm": jnp.ones((cfg.hidden,), jnp.float32),
            "wq": dense(next(keys), (cfg.hidden, qdim)),
            "wk": dense(next(keys), (cfg.hidden, kvdim)),
            "wv": dense(next(keys), (cfg.hidden, kvdim)),
            "wo": dense(next(keys), (qdim, cfg.hidden)),
            # Qwen2 attention QKV bias
            "bq": dense(next(keys), (1, qdim), 0.01)[0],
            "bk": dense(next(keys), (1, kvdim), 0.01)[0],
            "bv": dense(next(keys), (1, kvdim), 0.01)[0],
        }
        for name, shape in [
            ("gate", (cfg.hidden, cfg.ffn)),
            ("up", (cfg.hidden, cfg.ffn)),
            ("down", (cfg.ffn, cfg.hidden)),
        ]:
            w = dense(next(keys), shape)
            qw, s = quantize_q8(w)
            layer[f"w_{name}_q"] = qw
            layer[f"w_{name}_s"] = s
        params["layers"].append(layer)
    return params


def rmsnorm(x, weight, eps):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)) * weight


def rope(x, positions, theta):
    """Rotary embedding. x [..., T, H, D], positions [T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu_ffn(cfg: Config, layer, x):
    """SwiGLU FFN on q8_0 weights via the L1 Pallas qmatmul kernel."""
    gate = qmatmul_padded(x, layer["w_gate_q"], layer["w_gate_s"])
    up = qmatmul_padded(x, layer["w_up_q"], layer["w_up_s"])
    act = jax.nn.silu(gate) * up
    return qmatmul_padded(act, layer["w_down_q"], layer["w_down_s"])


def _project_qkv(cfg: Config, layer, x, positions):
    t = x.shape[0]
    q = (x @ layer["wq"] + layer["bq"]).reshape(t, cfg.q_heads, cfg.head_dim)
    k = (x @ layer["wk"] + layer["bk"]).reshape(t, cfg.kv_heads, cfg.head_dim)
    v = (x @ layer["wv"] + layer["bv"]).reshape(t, cfg.kv_heads, cfg.head_dim)
    return rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta), v


def _prefill_attention(cfg: Config, q, k, v):
    """Causal GQA attention over a whole prompt (plain jnp; the batched
    counterpart of the decode kernel)."""
    t = q.shape[0]
    group = cfg.q_heads // cfg.kv_heads
    kx = jnp.repeat(k, group, axis=1)  # [T, H, D]
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    scores = jnp.einsum("qhd,khd->hqk", q, kx) * scale
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, vx).reshape(t, -1)


def empty_cache(cfg: Config):
    shape = (cfg.layers, cfg.max_ctx, cfg.kv_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def prefill(cfg: Config, params, tokens):
    """tokens [T] i32 -> (logits [T, V], k_cache, v_cache).

    Caches are [L, max_ctx, KV, D] with rows [0, T) filled.
    """
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = params["embed"][tokens]
    k_cache, v_cache = empty_cache(cfg)
    for i, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, layer, h, positions)
        k_cache = k_cache.at[i, :t].set(k)
        v_cache = v_cache.at[i, :t].set(v)
        attn = _prefill_attention(cfg, q, k, v)
        x = x + attn @ layer["wo"]
        h = rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
        x = x + swiglu_ffn(cfg, layer, h)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    # tied embeddings; einsum (not `.T @`) so the traced HLO reuses the one
    # embedding constant instead of baking a second, transposed copy —
    # halves the big constants in the artifact (see EXPERIMENTS.md §Perf).
    logits = jnp.einsum("th,vh->tv", x, params["embed"])
    return logits, k_cache, v_cache


def decode_step(cfg: Config, params, token, k_cache, v_cache, pos):
    """One autoregressive step.

    token scalar i32; pos scalar i32 (the token's position; cache rows
    [0, pos) are valid). Returns (logits [V], k_cache, v_cache) with row
    `pos` appended. Attention runs through the L1 Pallas GQA kernel.
    """
    positions = jnp.asarray(pos, jnp.int32).reshape(1)
    x = params["embed"][token][None, :]  # [1, hidden]
    for i, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, layer, h, positions)
        zero = jnp.int32(0)
        idx = (jnp.int32(i), jnp.asarray(pos, jnp.int32), zero, zero)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None], idx)
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None], idx)
        attn = gqa_decode_attention(
            q[0], k_cache[i], v_cache[i], pos + 1, kv_heads=cfg.kv_heads
        ).reshape(1, -1)
        x = x + attn @ layer["wo"]
        h = rmsnorm(x, layer["ffn_norm"], cfg.norm_eps)
        x = x + swiglu_ffn(cfg, layer, h)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("th,vh->tv", x, params["embed"])[0]
    return logits, k_cache, v_cache


def greedy_generate(cfg: Config, params, prompt, steps: int):
    """Reference end-to-end generation (prefill + greedy decode)."""
    logits, kc, vc = prefill(cfg, params, prompt)
    token = jnp.argmax(logits[-1]).astype(jnp.int32)
    out = [int(token)]
    pos = prompt.shape[0]
    for _ in range(steps - 1):
        logits, kc, vc = decode_step(cfg, params, token, kc, vc, jnp.int32(pos))
        token = jnp.argmax(logits).astype(jnp.int32)
        out.append(int(token))
        pos += 1
    return out
