//! mixbench port (Konstantinidis & Cotronis, JPDC 2017) — CUDA flavor.
//!
//! mixbench sweeps *operational intensity*: for each `compute_iters` value
//! `c` it launches a kernel where every thread loads one element, runs `c`
//! fused multiply-adds on it, and stores the result. Reported flops/byte =
//! `2c / 4` for fp32 (the paper quotes 512.250 at c=1024, the +0.25 from the
//! index math). The sweep traces the roofline: bandwidth-bound at small `c`,
//! compute-bound at large `c`.
//!
//! The paper runs the CUDA build with default flags and with
//! `-fmad=false` injected through CMakeLists (Table 2-7). mixbench's launch
//! geometry (fixed 256-thread blocks over a modest buffer) leaves the GPU
//! slightly under-pressured versus OpenCL-Benchmark — §3.2/§3.4 call this
//! out — modeled here with a lower issue efficiency.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, Stmt, Traffic};
use crate::isa::pass::{apply_fmad, FmadPolicy};
use crate::sim::{batch, simulate_lowered, LoweredKernel, SimConfig};

use super::{Precision, ToolResult};

/// mixbench buffer: 64M elements (256 MiB fp32), the default VECTOR_SIZE
/// scaled to modern VRAM.
const ELEMENTS: u64 = 64 * 1024 * 1024;
const BLOCK: u32 = 256;

/// mixbench's CUDA launch sustains ~94% of peak issue on GA100 (its inner
/// loop carries a serial dependence chain).
const CUDA_ISSUE_EFF: f64 = 0.94;

/// mixbench's int8 kernel carries its accumulator through every dp4a —
/// the 4-cycle dependence chain stalls the CUDA build harder than the fp
/// pipes (Graph EX.1's 21.77 vs OpenCL's 25.13).
const CUDA_DP4A_CHAIN_EFF: f64 = 0.86;

fn sim_config(precision: Precision) -> SimConfig {
    SimConfig {
        issue_efficiency: if precision == Precision::Int8 {
            CUDA_DP4A_CHAIN_EFF
        } else {
            CUDA_ISSUE_EFF
        },
        ..Default::default()
    }
}

/// The per-thread fused op for a precision (what `-fmad=false` rewrites).
fn fused_class(precision: Precision) -> InstClass {
    match precision {
        Precision::Fp32 => InstClass::Ffma,
        Precision::Fp16Half2 => InstClass::Hfma2,
        Precision::Fp16Scalar => InstClass::Hfma,
        Precision::Fp64 => InstClass::Dfma,
        Precision::Int32 => InstClass::Imad,
        Precision::Int8 => InstClass::Dp4a,
    }
}

fn elem_bytes(precision: Precision) -> u64 {
    match precision {
        Precision::Fp16Half2 | Precision::Fp16Scalar => 2,
        Precision::Fp64 => 8,
        Precision::Int8 => 4, // dp4a consumes packed 4×i8 words
        _ => 4,
    }
}

/// Build the mixbench kernel for `compute_iters`.
pub fn kernel(precision: Precision, compute_iters: u64) -> Kernel {
    let class = fused_class(precision);
    let bytes = elem_bytes(precision);
    Kernel::new(
        format!("mixbench.{}.c{}", precision.name(), compute_iters),
        ELEMENTS,
        BLOCK,
    )
    .with_body(vec![
        Stmt::op(InstClass::Ldg, 1),
        Stmt::looped(compute_iters, vec![Stmt::op(class, 1)]),
        Stmt::op(InstClass::Stg, 1),
        // index arithmetic: one IMAD per element (the paper's "+0.250")
        Stmt::op(InstClass::Imad, 1),
    ])
    .with_traffic(Traffic::coalesced(ELEMENTS * bytes, ELEMENTS * bytes))
}

/// Flops/byte mixbench reports for a given `compute_iters`: traffic is one
/// element per thread (the store; the load is the same cache line), so the
/// fp32 axis reads (2c+1)/4 — 512.250 at c=1024, matching §3.2.
pub fn flops_per_byte(precision: Precision, compute_iters: u64) -> f64 {
    let class = fused_class(precision);
    let ops = class.flops().max(class.iops()) as f64;
    (compute_iters as f64 * ops + 1.0) / elem_bytes(precision) as f64
}

/// The one place a mixbench ToolResult label/timing pair is assembled —
/// shared by the single-point and batched paths so their labels can never
/// drift apart.
fn tool_result(
    precision: Precision,
    compute_iters: u64,
    policy: FmadPolicy,
    timing: crate::sim::KernelTiming,
) -> ToolResult {
    ToolResult {
        tool: "mixbench-cuda",
        case: format!("{} c={} {}", precision.name(), compute_iters, policy.name()),
        timing,
    }
}

/// One sweep point: simulate `compute_iters` at a given fmad policy.
pub fn run_point(
    dev: &DeviceSpec,
    precision: Precision,
    compute_iters: u64,
    policy: FmadPolicy,
) -> ToolResult {
    let lk = LoweredKernel::lower(&apply_fmad(&kernel(precision, compute_iters), policy));
    let timing = simulate_lowered(&lk, dev, &sim_config(precision));
    tool_result(precision, compute_iters, policy, timing)
}

/// The full operational-intensity sweep mixbench prints (powers of two up
/// to 1024 iterations, as in the paper's Table 2-7 runs). Each point is
/// lowered once and the whole sweep runs as one batched [`crate::sim::batch`]
/// pass.
pub fn sweep(dev: &DeviceSpec, precision: Precision, policy: FmadPolicy) -> Vec<ToolResult> {
    let mut iters = vec![0u64, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
    // mixbench also samples odd low-intensity points; keep the knee dense.
    iters.extend([3, 6, 12, 24, 48, 96]);
    iters.sort_unstable();
    let lowered: Vec<LoweredKernel> = iters
        .iter()
        .map(|&c| LoweredKernel::lower(&apply_fmad(&kernel(precision, c), policy)))
        .collect();
    let timings = batch::sweep(&lowered, std::slice::from_ref(dev), &sim_config(precision));
    iters
        .into_iter()
        .zip(timings)
        .map(|(c, timing)| tool_result(precision, c, policy, timing))
        .collect()
}

/// Peak rate over the sweep — the scalar the paper's Graph 3-x bars show.
pub fn peak(dev: &DeviceSpec, precision: Precision, policy: FmadPolicy) -> ToolResult {
    let mut results = sweep(dev, precision, policy);
    let integer = precision.integer();
    results
        .drain(..)
        .max_by(|a, b| {
            let (x, y) = if integer {
                (a.tiops(), b.tiops())
            } else {
                (a.tflops(), b.tflops())
            };
            x.partial_cmp(&y).unwrap()
        })
        .expect("sweep nonempty")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;

    #[test]
    fn flops_per_byte_matches_paper_at_1024() {
        // Paper §3.2: "1,024 compute iterations and a Flops/Byte ratio of
        // 512.250".
        let r = flops_per_byte(Precision::Fp32, 1024);
        assert!((r - 512.25).abs() < 0.5, "{r}");
    }

    #[test]
    fn sweep_crosses_from_memory_to_compute_bound() {
        let dev = registry::cmp170hx();
        let sweep = sweep(&dev, Precision::Fp32, FmadPolicy::Decomposed);
        assert!(sweep.first().unwrap().timing.memory_bound());
        assert!(!sweep.last().unwrap().timing.memory_bound());
    }

    #[test]
    fn fp32_peaks_match_graph_3_1() {
        let dev = registry::cmp170hx();
        let default = peak(&dev, Precision::Fp32, FmadPolicy::Fused).tflops();
        let nofma = peak(&dev, Precision::Fp32, FmadPolicy::Decomposed).tflops();
        assert!(
            cal::check(&cal::FP32_DEFAULT_TFLOPS, default),
            "default {default}"
        );
        // mixbench lands slightly under the OpenCL number; both within the
        // graph's band.
        assert!(nofma > 5.7 && nofma < 6.35, "nofma {nofma}");
        assert!(nofma / default > cal::FP32_RESTORE_FACTOR_MIN);
    }

    #[test]
    fn fp64_gets_worse_with_nofma() {
        let dev = registry::cmp170hx();
        let default = peak(&dev, Precision::Fp64, FmadPolicy::Fused).tflops();
        let nofma = peak(&dev, Precision::Fp64, FmadPolicy::Decomposed).tflops();
        assert!(cal::check(&cal::FP64_DEFAULT_TFLOPS, default), "{default}");
        assert!(nofma < default, "noFMA must hurt FP64: {nofma} vs {default}");
    }

    #[test]
    fn fp16_half2_is_fma_insensitive_and_near_50() {
        let dev = registry::cmp170hx();
        let default = peak(&dev, Precision::Fp16Half2, FmadPolicy::Fused).tflops();
        let nofma = peak(&dev, Precision::Fp16Half2, FmadPolicy::Decomposed).tflops();
        assert!(default > 45.0, "{default}");
        // Graph 3-2: FP16 "remains unaffected regardless of FMA status" —
        // packed-half mul/add dual-issue at 2× covers the decomposition.
        assert!((nofma / default - 1.0).abs() < 0.05, "{nofma} vs {default}");
    }

    #[test]
    fn batched_sweep_matches_single_points() {
        let dev = registry::cmp170hx();
        let sw = sweep(&dev, Precision::Fp32, FmadPolicy::Decomposed);
        for c in [0u64, 16, 1024] {
            let single = run_point(&dev, Precision::Fp32, c, FmadPolicy::Decomposed);
            let row = sw.iter().find(|r| r.case == single.case).unwrap();
            assert_eq!(row.timing.time_s.to_bits(), single.timing.time_s.to_bits());
        }
    }

    #[test]
    fn int32_is_uncrippled() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops();
        assert!(cal::check(&cal::INT32_CUDA_TIOPS, t), "{t}");
    }
}
