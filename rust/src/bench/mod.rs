//! Ports of the paper's benchmark tools (§1.3, §2.2.2).
//!
//! Each sub-module is a *workload generator*: it builds the same kernels the
//! real tool launches (same sweep axes, same launch pressure) and runs them
//! through [`crate::sim`]. Tool-specific character is expressed through
//! launch geometry and [`crate::sim::SimConfig`], not by scaling results —
//! the CUDA-vs-OpenCL deltas the paper observes fall out of launch pressure.
//!
//! | module | tool | figures |
//! |---|---|---|
//! | [`mixbench`] | mixbench (CUDA flavor) | Graphs 3-1…3-4 |
//! | [`openclbench`] | ProjectPhysX OpenCL-Benchmark | Graphs 3-1…3-5, EX.1 |
//! | [`gpuburn`] | GPU-Burn (control group, always default-compiled) | Graphs 3-1…3-3 |
//! | [`torchgemm`] | the paper's custom PyTorch matmul script | Graphs 3-1…3-3 |
//! | [`membench`] | OpenCL-Benchmark memory section | Graph 3-5 |
//! | [`pciebench`] | OpenCL-Benchmark PCIe section | Graph EX.2 |

pub mod gpuburn;
pub mod lbm;
pub mod membench;
pub mod mixbench;
pub mod openclbench;
pub mod pciebench;
pub mod torchgemm;

use crate::device::DeviceSpec;
use crate::isa::pass::FmadPolicy;
use crate::sim::KernelTiming;

/// A named benchmark result in the unit the paper's graph uses.
#[derive(Clone, Debug)]
pub struct ToolResult {
    pub tool: &'static str,
    pub case: String,
    pub timing: KernelTiming,
}

impl ToolResult {
    pub fn tflops(&self) -> f64 {
        self.timing.tflops()
    }
    pub fn tiops(&self) -> f64 {
        self.timing.tiops()
    }
    pub fn gbps(&self) -> f64 {
        self.timing.gbps()
    }
}

/// The precision axes of Graphs 3-1…3-4 and EX.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    /// Vectorized packed-half (OpenCL `half2`, mixbench-half): the path
    /// that reaches ~50 TFLOPS on the CMP 170HX.
    Fp16Half2,
    /// Scalar half (PyTorch / GPU-Burn): tops out at ~6.3 TFLOPS.
    Fp16Scalar,
    Fp64,
    Int32,
    Int8,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16Half2 => "fp16-half2",
            Precision::Fp16Scalar => "fp16-scalar",
            Precision::Fp64 => "fp64",
            Precision::Int32 => "int32",
            Precision::Int8 => "int8-dp4a",
        }
    }

    /// Is the paper's graph for this precision reported in TIOPs?
    pub fn integer(self) -> bool {
        matches!(self, Precision::Int32 | Precision::Int8)
    }
}

/// Run every tool the paper runs for one precision on one device, at both
/// fmad policies where the tool supports recompilation (GPU-Burn is the
/// paper's control group and is always default-compiled; the PyTorch script
/// inherits a prebuilt framework so its policy is fixed too — §5.3).
pub fn graph3_suite(dev: &DeviceSpec, precision: Precision) -> Vec<ToolResult> {
    let mut out = Vec::new();
    for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
        out.push(mixbench::peak(dev, precision, policy));
        out.push(openclbench::peak(dev, precision, policy));
    }
    out.push(gpuburn::run(dev, precision));
    out.push(torchgemm::run(dev, precision));
    out
}
